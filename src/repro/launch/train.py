"""Training driver with checkpoint/restart and fault-tolerant step loop.

Runs any registry arch at a --scale-reduced config on the local device(s), or
lowers the full config on the production mesh (see dryrun.py for that path).
Demonstrates the 1000+-node posture pieces end-to-end at container scale:

* checkpoint every --ckpt-every steps (params + opt state + data cursor),
  atomic publish, resume on restart (bit-exact; tested in tests/test_ckpt.py)
* simulated worker failure: --fail-at N raises mid-run; re-launching with the
  same --workdir resumes from the last checkpoint
* gradient accumulation (--accum) for large global batches
* optional int8-compressed gradient all-reduce with error feedback
  (--compress; wired through shard_map when a mesh is present)

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_moe --steps 50 \
      --scale 0.02 --workdir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import ckpt
from repro.configs import get
from repro.data import recsys_batch, random_graph, token_batch
from repro.optim import adamw


def scaled_lm_config(cfg, scale: float):
    from repro.models.lm import LMConfig, MoEConfig

    def r(x, mult=1):
        return max(mult, int(round(x * scale)) // mult * mult)

    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            d_ff_expert=r(cfg.moe.d_ff_expert, 8),
            d_ff_shared=r(cfg.moe.d_ff_shared, 8) if cfg.moe.n_shared else 0,
            e_pad=cfg.moe.e_pad or 0,
        )
    period = cfg.period
    tail = cfg.tail_local
    n_layers = max(period + tail, (cfg.n_layers * max(scale, 0.05)).__trunc__())
    n_layers = ((n_layers - tail) // period) * period + tail
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=r(cfg.d_model, 16),
        n_heads=max(2, r(cfg.n_heads, 2)),
        n_kv=max(1, min(cfg.n_kv, r(cfg.n_heads, 2) // 2)),
        d_head=r(cfg.d_head or cfg.d_model // cfg.n_heads, 8),
        d_ff=r(cfg.d_ff, 16) if cfg.d_ff else 0,
        vocab=r(cfg.vocab, 128),
        window=min(cfg.window, 64) if cfg.window else 0,
        q_chunk=64,
        dtype=jnp.float32,
        fsdp=False,
        moe=moe,
    )


def make_batch_fn(arch, cfg, batch: int, seq: int):
    if arch.family == "lm":
        def fn(step: int):
            return jnp.asarray(token_batch(batch, seq + 1, cfg.vocab, seed=step))
        return fn
    if arch.family == "recsys":
        def fn(step: int):
            b = recsys_batch(batch, cfg.n_sparse, cfg.table_rows,
                             seq_len=cfg.seq_len, seed=step)
            return {k: jnp.asarray(v) for k, v in b.items()}
        return fn
    if arch.family == "gnn":
        src, dst, feats = random_graph(512, 2048, 32, seed=0)
        tgt = np.random.default_rng(1).normal(size=(512, cfg.n_vars)).astype(np.float32)
        const = {"node_feats": jnp.asarray(feats), "src": jnp.asarray(src),
                 "dst": jnp.asarray(dst), "targets": jnp.asarray(tgt)}
        return lambda step: const
    raise ValueError(arch.family)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="simulate a node failure at this step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get(args.arch)
    key = jax.random.PRNGKey(args.seed)

    if arch.family == "lm":
        from repro.models.lm import transformer as tf
        cfg = scaled_lm_config(arch.config, args.scale)
        params = tf.init_params(cfg, key)
        step_fn = tf.make_train_step(cfg)
    elif arch.family == "recsys":
        from repro.models.recsys import models as rm
        cfg = dataclasses.replace(arch.config, table_rows=1 << 14)
        params = rm.init_params(cfg, key)
        step_fn = rm.make_train_step(cfg)
    elif arch.family == "gnn":
        from repro.models.gnn import graphcast as gc
        cfg = dataclasses.replace(arch.config, n_layers=4, d_hidden=64)
        params = gc.init_params(cfg, 32, key)
        step_fn = gc.make_train_step(cfg)
    else:
        raise SystemExit(f"train.py does not drive family {arch.family!r}; "
                         "use launch/serve.py for the ANNS engine")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={arch.name} scaled params={n_params/1e6:.1f}M")
    opt_state = adamw.init(params)
    batch_fn = make_batch_fn(arch, cfg, args.batch, args.seq)

    start = 0
    ckpt_root = os.path.join(args.workdir, "ckpt")
    if ckpt.latest_step(ckpt_root) is not None:
        (params, opt_state), start, extra = ckpt.restore((params, opt_state), ckpt_root)
        print(f"resumed from step {start} (cursor={extra.get('cursor')})")

    if args.accum > 1:
        base = step_fn

        def accum_step(params, opt_state, batches):
            # grad-accum: average loss grads over microbatches via lax.scan
            def loss_of(p, b):
                if arch.family == "lm":
                    from repro.models.lm import transformer as tf
                    return tf.loss_fn(p, b, cfg)
                raise NotImplementedError

            def body(g_acc, b):
                _, g = jax.value_and_grad(loss_of)(params, b)
                return jax.tree.map(jnp.add, g_acc, g), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            g, _ = jax.lax.scan(body, zeros, batches)
            g = jax.tree.map(lambda x: x / args.accum, g)
            p2, o2, m = adamw.apply(params, g, opt_state, adamw.AdamWConfig())
            return p2, o2, m

        step_fn = accum_step

    jit_step = jax.jit(step_fn)
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        if step == args.fail_at:
            raise RuntimeError(f"simulated node failure at step {step}")
        if args.accum > 1 and arch.family == "lm":
            batch = jnp.stack([batch_fn(step * args.accum + i)
                               for i in range(args.accum)])
        else:
            batch = batch_fn(step)
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.perf_counter()-t0:.1f}s)", flush=True)
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            ckpt.save((params, opt_state), step + 1, ckpt_root,
                      extra={"cursor": step + 1})
    print("done")


if __name__ == "__main__":
    main()
