from .synthetic import (
    PAPER_DATASETS,
    VectorDatasetSpec,
    make_queries,
    make_vectors,
    neighbor_sample,
    random_graph,
    recsys_batch,
    token_batch,
)
