"""Synthetic data generators fitted to the paper's workload shapes (Table 2,
Fig. 1) plus token/graph/recsys feeds for the assigned architectures.

Vector corpora are Gaussian-mixture clustered (real embedding corpora are
strongly clustered — that is the premise of clustering-based ANNS); queries
are sampled near corpus modes with temperature, and per-query top-k follows
each service's production range (Fig. 1c).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class VectorDatasetSpec:
    name: str
    n: int
    dim: int
    topk_lo: int
    topk_hi: int
    n_modes: int = 64
    spread: float = 0.25     # intra-cluster std relative to inter-mode std
    seed: int = 0


# Table 2, scaled to container-feasible sizes (scale factor recorded so the
# benchmarks can report the paper-relative setting).
PAPER_DATASETS = {
    "sift":    VectorDatasetSpec("sift",    100_000, 128, 10, 3000, seed=1),
    "redsrch": VectorDatasetSpec("redsrch", 200_000,  64, 100, 3000, seed=2),
    "redrec":  VectorDatasetSpec("redrec",  100_000,  64, 100, 1000, seed=3),
    "redads":  VectorDatasetSpec("redads",   50_000, 128, 100, 3000, seed=4),
    "redcm":   VectorDatasetSpec("redcm",   100_000,  64, 100,  500, seed=5),
    "redrag":  VectorDatasetSpec("redrag",   20_000, 1024, 10,  100, seed=6),
}


def make_vectors(spec: VectorDatasetSpec) -> np.ndarray:
    rng = np.random.default_rng(spec.seed)
    modes = rng.normal(size=(spec.n_modes, spec.dim)).astype(np.float32)
    weights = rng.dirichlet(np.full(spec.n_modes, 1.5))
    which = rng.choice(spec.n_modes, size=spec.n, p=weights)
    x = modes[which] + spec.spread * rng.normal(size=(spec.n, spec.dim))
    return x.astype(np.float32)


def make_queries(
    spec: VectorDatasetSpec, n_queries: int, temp: float = 1.2, seed: int = 100
) -> tuple[np.ndarray, np.ndarray]:
    """Queries near corpus modes + per-query top-k from the service range
    (log-uniform — production top-k is heavy at the low end)."""
    rng = np.random.default_rng(spec.seed + seed)
    modes = np.random.default_rng(spec.seed).normal(
        size=(spec.n_modes, spec.dim)
    ).astype(np.float32)
    which = rng.choice(spec.n_modes, size=n_queries)
    q = modes[which] + temp * spec.spread * rng.normal(size=(n_queries, spec.dim))
    lo, hi = np.log(spec.topk_lo), np.log(spec.topk_hi)
    topk = np.exp(rng.uniform(lo, hi, size=n_queries)).astype(np.int32)
    return q.astype(np.float32), np.clip(topk, spec.topk_lo, spec.topk_hi)


# ---------------------------------------------------------------------------
# model-zoo feeds
# ---------------------------------------------------------------------------
def token_batch(batch: int, seq: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, vocab, size=(batch, seq), dtype=np.int32)
    return tokens


def random_graph(n_nodes: int, n_edges: int, d_feat: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    return src, dst, feats


def neighbor_sample(
    src: np.ndarray,
    dst: np.ndarray,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    rng: Optional[np.random.Generator] = None,
):
    """Layered neighbor sampler (GraphSAGE-style) for ``minibatch_lg``.

    Returns per-layer (edge_src, edge_dst) index arrays into the global node
    id space, plus the final frontier.  CSR built once, sampled per batch.
    """
    if rng is None:
        rng = np.random.default_rng(0)
    order = np.argsort(dst, kind="stable")
    s_sorted, d_sorted = src[order], dst[order]
    n_nodes = int(max(src.max(initial=0), dst.max(initial=0))) + 1
    starts = np.searchsorted(d_sorted, np.arange(n_nodes))
    ends = np.searchsorted(d_sorted, np.arange(n_nodes) + 1)

    frontier = np.unique(seeds)
    layers = []
    for f in fanouts:
        es, ed = [], []
        for v in frontier:
            nbrs = s_sorted[starts[v]:ends[v]]
            if nbrs.size == 0:
                continue
            take = nbrs if nbrs.size <= f else rng.choice(nbrs, size=f, replace=False)
            es.append(take)
            ed.append(np.full(take.size, v, dtype=np.int32))
        if es:
            es = np.concatenate(es).astype(np.int32)
            ed = np.concatenate(ed).astype(np.int32)
        else:
            es = np.zeros(0, np.int32)
            ed = np.zeros(0, np.int32)
        layers.append((es, ed))
        frontier = np.unique(np.concatenate([frontier, es]))
    return layers, frontier


def recsys_batch(
    batch: int,
    n_sparse: int,
    table_rows: int,
    seq_len: int = 0,
    seed: int = 0,
):
    """Zipf-distributed sparse ids (production id popularity is zipfian) +
    optional behaviour sequence for DIN/MIND."""
    rng = np.random.default_rng(seed)
    ids = (rng.zipf(1.3, size=(batch, n_sparse)) - 1) % table_rows
    out = {"sparse_ids": ids.astype(np.int32),
           "labels": rng.integers(0, 2, size=(batch,)).astype(np.float32)}
    if seq_len:
        seq = (rng.zipf(1.3, size=(batch, seq_len)) - 1) % table_rows
        length = rng.integers(1, seq_len + 1, size=(batch,))
        out["hist_ids"] = seq.astype(np.int32)
        out["hist_len"] = length.astype(np.int32)
    return out
