"""Load generation for the serving runtime — the traffic side of §4.1.

The paper evaluates its userspace I/O stack under production query streams
("heavy traffic from millions of users"): open-loop arrival processes that
keep issuing work whether or not the server keeps up (the back-pressure /
admission-control regime), and closed-loop clients that wait for their
previous answer (the latency-measurement regime).  This module generates
deterministic, seeded versions of both:

* ``poisson_trace``    — memoryless open-loop arrivals at a target QPS;
* ``bursty_trace``     — piecewise-Poisson on/off bursts (the diurnal +
                         flash-crowd shape of Fig. 1 traffic);
* ``multi_tenant_trace`` — superposition of per-index traces for the §4.2
                         multi-index node (each tenant its own rate, top-k
                         range, and deadline budget);
* ``locality_skewed_trace`` — ``concurrency`` independent user streams, each
                         pinned (with slow Markov drift) to one contiguous
                         GROUP of the query pool; arrivals from different
                         groups interleave in time, so arrival-order
                         batching mixes groups while locality-aware
                         formation can unmix them (the FIFO-vs-locality
                         A/B's worst case for FIFO, and the shape of real
                         traffic: many concurrent users, each on a topic);
* ``hot_cluster_trace`` — a hot subset of the query pool takes most of the
                         traffic (hot-cluster / celebrity-item skew): the
                         batch union is dominated by a few clusters that
                         every batch re-gathers;
* ``drifting_trace``   — a sliding query-pool window migrates across the
                         cluster space over the trace (distribution drift:
                         what the centroid-drift monitor and recall-proxy
                         histograms are built to catch).

Traces are plain lists of :class:`Arrival` sorted by time — the engine tests
replay them against a virtual clock, so every admission/shedding decision is
reproducible bit-for-bit from (trace seed, policy).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One query arrival: time is seconds from trace start (virtual clock)."""
    t: float
    index: str                     # which co-resident index this query hits
    qrow: int                      # row into the tenant's query pool
    topk: int
    deadline_s: Optional[float]    # latency budget (None = best-effort)

    def deadline_at(self, t0: float) -> Optional[float]:
        return None if self.deadline_s is None else t0 + self.t + self.deadline_s


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Per-tenant traffic shape for multi-index mixes."""
    index: str
    rate_qps: float
    topk_lo: int = 10
    topk_hi: int = 100
    deadline_s: Optional[float] = None
    n_queries: int = 1 << 30       # query-pool size qrow is drawn from


def _draw_arrivals(
    rng: np.random.Generator,
    spec: TenantSpec,
    duration_s: float,
    rate_fn=None,
) -> list[Arrival]:
    """Thinned Poisson process: homogeneous at spec.rate_qps, or modulated by
    ``rate_fn(t) in [0, 1]`` (Lewis–Shedler thinning, so bursty traces stay
    exactly Poisson within each regime)."""
    out: list[Arrival] = []
    t = 0.0
    if spec.rate_qps <= 0:
        return out
    while True:
        t += rng.exponential(1.0 / spec.rate_qps)
        if t >= duration_s:
            break
        if rate_fn is not None and rng.uniform() > rate_fn(t):
            continue
        topk = int(np.exp(rng.uniform(np.log(spec.topk_lo),
                                      np.log(spec.topk_hi + 1))))
        topk = min(max(topk, spec.topk_lo), spec.topk_hi)
        out.append(Arrival(t=float(t), index=spec.index,
                           qrow=int(rng.integers(0, spec.n_queries)),
                           topk=topk, deadline_s=spec.deadline_s))
    return out


def poisson_trace(
    rate_qps: float,
    duration_s: float,
    seed: int = 0,
    index: str = "default",
    topk: tuple[int, int] = (10, 100),
    deadline_s: Optional[float] = None,
    n_queries: int = 1 << 30,
) -> list[Arrival]:
    """Open-loop memoryless arrivals at ``rate_qps`` for ``duration_s``."""
    rng = np.random.default_rng(seed)
    spec = TenantSpec(index, rate_qps, topk[0], topk[1], deadline_s, n_queries)
    return _draw_arrivals(rng, spec, duration_s)


def bursty_trace(
    base_qps: float,
    burst_qps: float,
    period_s: float,
    duty: float,
    duration_s: float,
    seed: int = 0,
    index: str = "default",
    topk: tuple[int, int] = (10, 100),
    deadline_s: Optional[float] = None,
    n_queries: int = 1 << 30,
) -> list[Arrival]:
    """On/off bursts: ``burst_qps`` for the first ``duty`` fraction of every
    ``period_s`` window, ``base_qps`` otherwise (flash-crowd shape)."""
    rng = np.random.default_rng(seed)
    peak = max(base_qps, burst_qps)
    spec = TenantSpec(index, peak, topk[0], topk[1], deadline_s, n_queries)

    def rate_fn(t: float) -> float:
        in_burst = (t % period_s) < duty * period_s
        return (burst_qps if in_burst else base_qps) / peak

    return _draw_arrivals(rng, spec, duration_s, rate_fn)


def locality_skewed_trace(
    rate_qps: float,
    duration_s: float,
    n_queries: int,
    n_groups: int = 16,
    concurrency: int = 8,
    switch_p: float = 0.02,
    seed: int = 0,
    index: str = "default",
    topk: tuple[int, int] = (10, 100),
    deadline_s: Optional[float] = None,
) -> list[Arrival]:
    """Locality-skewed open-loop arrivals: ``concurrency`` independent
    Poisson user streams (rate_qps split evenly), each drawing qrows from
    ONE of ``n_groups`` contiguous slices of the query pool and switching to
    a fresh random group with probability ``switch_p`` per arrival (slow
    topic drift).  Callers that want qrow-contiguity to mean probe-locality
    sort their query pool by nearest centroid first — then each group is a
    tight probed-cluster neighborhood, and the merged timeline interleaves
    ~``concurrency`` neighborhoods at any instant.  Each stream draws from
    its own derived seed, but note the total rate is split evenly, so
    changing ``concurrency`` reshapes every stream's arrival times (hold it
    fixed across paired A/B runs)."""
    if n_groups <= 0 or n_queries < n_groups:
        raise ValueError(f"need n_queries >= n_groups ({n_queries} < {n_groups})")
    group_size = n_queries // n_groups
    streams = []
    for s in range(max(int(concurrency), 1)):
        rng = np.random.default_rng(np.random.SeedSequence([seed, 11, s]))
        spec = TenantSpec(index, rate_qps / max(int(concurrency), 1),
                          topk[0], topk[1], deadline_s, n_queries)
        raw = _draw_arrivals(rng, spec, duration_s)
        g = int(rng.integers(0, n_groups))
        out = []
        for a in raw:
            if rng.uniform() < switch_p:
                g = int(rng.integers(0, n_groups))
            qrow = g * group_size + int(rng.integers(0, group_size))
            out.append(dataclasses.replace(a, qrow=qrow))
        streams.append(out)
    return list(heapq.merge(*streams, key=lambda a: a.t))


def hot_cluster_trace(
    rate_qps: float,
    duration_s: float,
    n_queries: int,
    hot_frac: float = 0.05,
    hot_weight: float = 0.9,
    seed: int = 0,
    index: str = "default",
    topk: tuple[int, int] = (10, 100),
    deadline_s: Optional[float] = None,
) -> list[Arrival]:
    """Hot-cluster skew: ``hot_weight`` of the traffic draws qrows from the
    first ``hot_frac`` slice of the query pool, the rest uniformly from the
    whole pool.  With a centroid-sorted pool the hot slice maps to a handful
    of clusters — the celebrity-item regime where most batches should share
    most of their gather union."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 13]))
    spec = TenantSpec(index, rate_qps, topk[0], topk[1], deadline_s, n_queries)
    raw = _draw_arrivals(rng, spec, duration_s)
    n_hot = max(int(n_queries * hot_frac), 1)
    out = []
    for a in raw:
        if rng.uniform() < hot_weight:
            qrow = int(rng.integers(0, n_hot))
        else:
            qrow = int(rng.integers(0, n_queries))
        out.append(dataclasses.replace(a, qrow=qrow))
    return out


def shard_skewed_trace(
    rate_qps: float,
    duration_s: float,
    n_queries: int,
    hot_rows: Sequence[int],
    hot_weight: float = 0.9,
    seed: int = 0,
    index: str = "default",
    topk: tuple[int, int] = (10, 100),
    deadline_s: Optional[float] = None,
) -> list[Arrival]:
    """Shard-skewed arrivals for the fabric drills: ``hot_weight`` of the
    traffic draws qrows from ``hot_rows`` — the caller passes the query rows
    whose nearest centroid lives on ONE shard (``ShardedFabric.
    query_shards``) — the rest uniformly from the whole pool.  One shard
    therefore absorbs most of the fan-out (the replication + kill-drill
    target), and the whole trace is a pure function of ``seed``."""
    hot_rows = np.asarray(hot_rows, np.int64)
    if hot_rows.size == 0:
        raise ValueError("shard_skewed_trace needs a non-empty hot_rows")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 17]))
    spec = TenantSpec(index, rate_qps, topk[0], topk[1], deadline_s, n_queries)
    raw = _draw_arrivals(rng, spec, duration_s)
    out = []
    for a in raw:
        if rng.uniform() < hot_weight:
            qrow = int(hot_rows[int(rng.integers(0, hot_rows.size))])
        else:
            qrow = int(rng.integers(0, n_queries))
        out.append(dataclasses.replace(a, qrow=qrow))
    return out


def drifting_trace(
    rate_qps: float,
    duration_s: float,
    n_queries: int,
    window_frac: float = 0.25,
    seed: int = 0,
    index: str = "default",
    topk: tuple[int, int] = (10, 100),
    deadline_s: Optional[float] = None,
) -> list[Arrival]:
    """Distribution-drift arrivals for the quality-observability drills: a
    contiguous window of ``window_frac`` of the query pool slides from the
    pool's start to its end over the trace duration, and every qrow is
    drawn from the CURRENT window.  With a centroid-sorted pool the query
    distribution therefore migrates across the cluster space — early
    traffic probes the first clusters, late traffic the last — which is
    the workload shape the centroid-drift monitor and the per-route
    recall-proxy histograms exist to catch.  Pure function of ``seed``."""
    if not 0.0 < window_frac <= 1.0:
        raise ValueError(f"window_frac must be in (0, 1], got {window_frac}")
    win = max(int(n_queries * window_frac), 1)
    span = max(n_queries - win, 0)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 19]))
    spec = TenantSpec(index, rate_qps, topk[0], topk[1], deadline_s,
                      n_queries)
    raw = _draw_arrivals(rng, spec, duration_s)
    out = []
    for a in raw:
        lo = int(span * min(a.t / max(duration_s, 1e-9), 1.0))
        qrow = lo + int(rng.integers(0, win))
        out.append(dataclasses.replace(a, qrow=qrow))
    return out


@dataclasses.dataclass(frozen=True)
class UpdateArrival:
    """One update-lane arrival (lifecycle ingest): an insert of ``n`` new
    vectors or a delete of ``n`` live ids, at trace time ``t``."""
    t: float
    op: str                        # "insert" | "delete"
    n: int = 1
    index: str = "default"


def update_trace(
    insert_ops_s: float,
    delete_ops_s: float,
    duration_s: float,
    seed: int = 0,
    index: str = "default",
    batch: int = 1,
) -> list[UpdateArrival]:
    """Open-loop update stream: independent Poisson insert/delete processes,
    time-merged.  ``batch`` vectors/ids ride each op (the client-side
    batching real ingest pipelines do).  Seeded per-op-type so changing one
    rate does not perturb the other stream's arrivals."""
    streams = []
    for i, (op, rate) in enumerate((("insert", insert_ops_s),
                                    ("delete", delete_ops_s))):
        if rate <= 0:
            continue
        rng = np.random.default_rng(np.random.SeedSequence([seed, 7, i]))
        out, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= duration_s:
                break
            out.append(UpdateArrival(t=float(t), op=op, n=batch, index=index))
        streams.append(out)
    return list(heapq.merge(*streams, key=lambda a: a.t))


def merge_timelines(*traces):
    """Time-merge heterogeneous arrival lists (search + update) into one
    replayable stream — every element keeps its own type, sorted by .t."""
    return list(heapq.merge(*traces, key=lambda a: a.t))


def multi_tenant_trace(
    tenants: Sequence[TenantSpec],
    duration_s: float,
    seed: int = 0,
) -> list[Arrival]:
    """Superposition of independent per-tenant Poisson streams, time-merged.

    Each tenant gets a derived seed, so adding a tenant does not perturb the
    other tenants' arrivals (important for fairness A/Bs)."""
    streams = []
    for i, spec in enumerate(tenants):
        rng = np.random.default_rng(np.random.SeedSequence([seed, i]))
        streams.append(_draw_arrivals(rng, spec, duration_s))
    return list(heapq.merge(*streams, key=lambda a: a.t))
