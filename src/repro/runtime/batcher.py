"""Dynamic micro-batching + deadline-aware admission control (§4.1/§4.2).

TPU serving wants batches (one doorbell per batch, MXU-shaped work), but
traffic arrives one query at a time.  The batcher sits between the
submission queue and the scan pipeline and makes four decisions the paper's
runtime makes in its userspace stack:

* **coalescing** — accumulate single-query arrivals per index and release a
  micro-batch when it is full (``max_batch``) or its head-of-line request
  has waited ``max_wait_s`` (bounded batching delay);
* **locality grouping** — the packed scan distances every query in a batch
  against the batch's whole probed-cluster *union*, and the host tier
  gathers that union per batch; a batch of queries that probe the same
  clusters therefore costs a fraction of an arrival-order batch (the §4.1
  dependency-free batched-I/O economics, and FusionANNS's group-by-locality
  lesson).  When requests carry an admission-time :class:`RoutePlan`
  (cheap, pre-search features only — the §4.3 compatibility constraint),
  ``form`` packs greedily by probe-set overlap: every request older than
  ``max_wait_s`` is seeded FIFO (the aging guard — locality can reorder,
  never starve), then remaining slots go to the pending request whose probe
  set grows the running union least;
* **admission control / shedding** — a request whose deadline cannot be met
  even by the *fastest* path is completed immediately as ``shed`` (fail fast
  beats queueing doomed work — the paper's overload posture); a request that
  would miss its deadline at the routed LLSP level but could make it at a
  cheaper level is **degraded**: its nprobe is capped (``degrade_nprobe``),
  trading recall for latency instead of dropping the query.  Estimates are
  iterated to a fixed point on the *kept* set: shedding one doomed request
  shrinks the batch, and the survivors are re-judged against the batch that
  will actually run — never against peers that were themselves just shed;
* **fairness** — micro-batches are released round-robin across the node's
  co-resident indexes (§4.2 multi-index hosting), so a hot tenant cannot
  starve a cold one; within an index, FIFO order is preserved inside each
  released batch (selection can skip, the emitted request order cannot
  reorder).

All decisions are functions of (policy, observed-EWMA service rate, ``now``,
admission-time routes) only — replaying a seeded arrival trace against a
virtual clock reproduces the exact shed/degrade/batch sequence, which is
what the determinism tests assert.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from .engine import Completion, SearchRequest

_EMPTY_PROBES: frozenset = frozenset()


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    max_batch: int = 64            # release when this many are pending
    max_wait_s: float = 0.005      # ... or when head-of-line waited this long
    pad: int = 16                  # micro-batch quantum; keep equal to the
                                   # pipeline's pad_batch (the actual jit
                                   # padding knob) so warmups cover the
                                   # shapes the pipeline really compiles
    shed: str = "degrade"          # "none" | "shed" | "degrade"
    degrade_nprobe: int = 8        # nprobe cap for degraded requests
                                   # (lowest LLSP level bound)
    degrade_speedup: float = 2.0   # assumed service speedup of a degraded req
    overhead_s: float = 1e-3       # fixed per-batch cost (dispatch + merge)
    init_query_s: float = 1e-4     # prior per-query service estimate
    ewma: float = 0.3              # service-estimate smoothing
    update_quantum: int = 64       # max update-lane ops the poller applies
                                   # between search batches — bounds how much
                                   # an update storm can delay the next
                                   # micro-batch (storms back-pressure their
                                   # own SQ instead of starving search)
    grouping: str = "locality"     # "locality" | "fifo" micro-batch formation
                                   # (fifo = pre-PR-5 arrival order; requests
                                   # without a RoutePlan degrade to fifo
                                   # order under "locality" too)
    union_growth_cap: int = 0      # locality mode: stop filling a batch when
                                   # the best remaining candidate would add
                                   # more than this many new clusters to the
                                   # union (0 = always fill to max_batch);
                                   # skipped requests age into the next
                                   # batch's FIFO seed, so the cap trades
                                   # batch size for union tightness without
                                   # starving anyone


@dataclasses.dataclass
class MicroBatch:
    index: str
    requests: list                 # list[SearchRequest], FIFO
    nprobe_cap: np.ndarray         # (b,) int32, 0 = uncapped
    degraded: np.ndarray           # (b,) bool
    formed_at: float
    waits: Optional[np.ndarray] = None   # (b,) seconds in queue at formation
    probe_union: Optional[frozenset] = None  # union of admission-time probe
                                             # sets (None: no routed request)


@dataclasses.dataclass
class BatcherStats:
    admitted: int = 0
    shed_admission: int = 0        # dead on arrival (deadline unmeetable)
    shed_deadline: int = 0         # dropped at batch formation
    degraded: int = 0
    batches: int = 0
    locality_batches: int = 0      # batches formed by probe-overlap packing
    aged_seeds: int = 0            # requests force-seeded by the aging guard
    max_queue_wait_s: float = 0.0  # worst formation wait seen (aging bound
                                   # evidence: compare against max_wait_s)


def _probe_set(req: SearchRequest) -> frozenset:
    route = getattr(req, "route", None)
    return _EMPTY_PROBES if route is None else route.probe_set


class DynamicBatcher:
    """Per-index pending queues + round-robin micro-batch formation."""

    def __init__(self, policy: BatchPolicy, indexes: list[str]):
        self.policy = policy
        self._pending: dict[str, collections.deque] = {
            name: collections.deque() for name in indexes
        }
        self._rr = 0                       # round-robin cursor over indexes
        self.est_query_s = policy.init_query_s
        self.stats = BatcherStats()
        # per-index probe routers (set by the engine): called with the list
        # of still-unrouted pending requests ONCE per formation, so trickle
        # arrivals amortize the centroid+LLSP call over the whole pool
        # instead of paying a per-arrival jit dispatch
        self.routers: dict = {}

    @property
    def indexes(self) -> list[str]:
        return list(self._pending)

    def add_index(self, name: str) -> None:
        if name in self._pending:
            return
        # copy-on-write: the poller thread iterates self._pending without a
        # lock, so mutate by swapping in a new dict (atomic attribute store)
        # rather than inserting into the one being iterated
        self._pending = {**self._pending, name: collections.deque()}

    def pending(self, index: Optional[str] = None) -> int:
        if index is not None:
            return len(self._pending[index])
        return sum(len(q) for q in self._pending.values())

    def drain_pending(self) -> list:
        """Pull EVERY pending request out, FIFO within each index, indexes
        in registration order — the engine's no-drain shutdown path
        completes these as shed instead of abandoning them."""
        out: list = []
        for q in self._pending.values():
            while q:
                out.append(q.popleft())
        return out

    def observe(self, batch_size: int, service_s: float) -> None:
        """Fold a measured batch service time into the per-query EWMA."""
        if batch_size <= 0:
            return
        per_q = max(service_s - self.policy.overhead_s, 0.0) / batch_size
        a = self.policy.ewma
        self.est_query_s = (1 - a) * self.est_query_s + a * per_q

    # -- admission ---------------------------------------------------------
    def _min_latency(self, degraded: bool = False) -> float:
        est = self.policy.overhead_s + self.est_query_s
        return est / self.policy.degrade_speedup if degraded else est

    def add(self, req: SearchRequest, now: float) -> Optional[Completion]:
        """Admit a request; returns a shed Completion if it is dead on
        arrival (deadline unmeetable even solo + degraded), else None."""
        if req.index not in self._pending:
            raise KeyError(f"unknown index {req.index!r}")
        if req.deadline is not None and (
            now + self._min_latency(degraded=True) > req.deadline
        ):
            self.stats.shed_admission += 1
            return Completion(
                req_id=req.req_id, index=req.index, status="shed",
                ids=None, dists=None, nprobe=0,
                submitted=req.arrival, completed=now,
                reason="deadline", trace_id=req.trace_id,
            )
        self.stats.admitted += 1
        self._pending[req.index].append(req)
        return None

    # -- batch formation ---------------------------------------------------
    def _due(self, q: collections.deque, now: float) -> bool:
        """THE release predicate (shared by ready() and form(), so the two
        cannot drift): a queue is due when it can fill a batch or its
        head-of-line request has aged past the batching-delay bound."""
        if len(q) >= self.policy.max_batch:
            return True
        return bool(q) and now - q[0].arrival >= self.policy.max_wait_s

    def ready(self, now: float) -> bool:
        """Is some index due for release (full batch or head-of-line aged)?"""
        return any(self._due(q, now) for q in self._pending.values())

    def _pick_index(self, now: float, force: bool) -> Optional[str]:
        """Round-robin scan from the cursor; ``force`` takes any non-empty
        queue (drain path).  Advancing the cursor by scan offset — never by
        name lookup — keeps the drain order a deterministic function of
        (queue state, cursor), independent of how indexes were added."""
        names = list(self._pending)
        for off in range(len(names)):
            name = names[(self._rr + off) % len(names)]
            q = self._pending[name]
            if not q:
                continue
            if force or self._due(q, now):
                self._rr = (self._rr + off + 1) % len(names)
                return name
        return None

    def _select(self, name: str, q: collections.deque, now: float,
                force: bool) -> list[SearchRequest]:
        """Pull the next batch's requests out of ``q``.

        FIFO mode (or force-drain, or no routed request pending): the oldest
        ``max_batch`` requests, arrival order — exactly the pre-locality
        behavior, and the A/B baseline.

        Locality mode: every request older than ``max_wait_s`` is seeded
        first in FIFO order (aging guard — grouping may skip a request for
        at most one release cycle before it becomes a mandatory seed), then
        remaining slots are filled greedily with the request whose
        admission-time probe set adds the fewest new clusters to the running
        union (ties broken by arrival order, so unrouted requests — growth 0
        — degrade to FIFO).  The emitted list is re-sorted to arrival order:
        selection chooses *membership*, never response order.
        """
        limit = self.policy.max_batch
        snap = list(q)
        if self.policy.grouping == "locality" and not force:
            router = self.routers.get(name)
            if router is not None and snap:
                # one pooled centroid+LLSP call; the router itself skips
                # requests already routed by the LIVE pipeline, so this is
                # a no-op pass when everything is fresh but re-routes a
                # pool whose routes went stale across an epoch swap
                router(snap)
        locality = (self.policy.grouping == "locality" and not force
                    and any(_probe_set(r) for r in snap))
        if not locality:
            take = snap[:limit]
            for _ in take:
                q.popleft()
            return take
        aged = [i for i, r in enumerate(snap)
                if now - r.arrival >= self.policy.max_wait_s]
        sel = aged[:limit]
        self.stats.aged_seeds += len(sel)
        if not sel:
            sel = [0]                      # anchor on head-of-line
        chosen = set(sel)
        # vectorized greedy over cluster bitsets: the selection runs on the
        # poller's critical path, so the inner argmin is ONE numpy op over
        # (pool, C) bools per added request, not a python set loop — a
        # multi-hundred-request backlog must not stall batch release
        probes = [_probe_set(r) for r in snap]
        n_bits = 1 + max((max(p) for p in probes if p), default=0)
        bits = np.zeros((len(snap), n_bits), bool)
        for i, (r, p) in enumerate(zip(snap, probes)):
            if not p:
                continue
            rb = r.route
            if rb is not None:
                # cache the request's bit row on its RoutePlan: a pool
                # persists across formations, so the set -> bitset
                # conversion happens once per request, not once per batch
                if rb.bits is None:
                    rb.bits = np.zeros(max(p) + 1, bool)
                    rb.bits[list(p)] = True
                bits[i, : rb.bits.size] = rb.bits
            else:
                bits[i, list(p)] = True
        union = np.zeros(n_bits, bool)
        for i in sel:
            union |= bits[i]
        remaining = np.asarray(
            [i for i in range(len(snap)) if i not in chosen], np.int64)
        cap = self.policy.union_growth_cap
        while len(sel) < limit and remaining.size:
            growth = (bits[remaining] & ~union).sum(axis=1)
            pos = int(np.argmin(growth))   # first min = oldest (FIFO ties)
            if cap and int(growth[pos]) > cap:
                break                      # bounded union growth: leave the
                                           # outlier to age into the next
                                           # batch's mandatory seed
            best = int(remaining[pos])
            sel.append(best)
            chosen.add(best)
            union |= bits[best]
            remaining = np.delete(remaining, pos)
        take = [snap[i] for i in sorted(sel)]
        q.clear()
        q.extend(snap[i] for i in range(len(snap)) if i not in chosen)
        self.stats.locality_batches += 1
        return take

    def _admit(self, reqs: list[SearchRequest], now: float
               ) -> tuple[list[SearchRequest], np.ndarray, np.ndarray,
                          list[SearchRequest]]:
        """Deadline admission on a formed batch, iterated to a fixed point.

        The service estimate is a function of the batch size that actually
        runs, so shedding is iterative: drop the single most-doomed request
        (earliest deadline among those missing even the relaxed bound),
        re-estimate on the smaller batch, repeat.  A survivor is therefore
        never shed — or degraded — because of peers that were themselves
        just shed (the pre-PR-5 bug judged everyone against the pre-shed
        batch size, over-shedding exactly at the deadline boundary)."""
        pol = self.policy
        keep = list(reqs)
        sheds: list[SearchRequest] = []
        if pol.shed != "none":
            while keep:
                b = len(keep)
                est_relaxed = pol.overhead_s + self.est_query_s * b
                if pol.shed == "degrade":
                    est_relaxed = pol.overhead_s + (
                        self.est_query_s * b / pol.degrade_speedup)
                doomed = [r for r in keep if r.deadline is not None
                          and now + est_relaxed > r.deadline]
                if not doomed:
                    break
                victim = min(doomed, key=lambda r: r.deadline)
                keep.remove(victim)
                sheds.append(victim)
        b = len(keep)
        est_full = pol.overhead_s + self.est_query_s * b
        cap = np.zeros((b,), np.int32)
        deg = np.zeros((b,), bool)
        if pol.shed == "degrade":
            for i, r in enumerate(keep):
                if r.deadline is not None and now + est_full > r.deadline:
                    # fits the degraded bound by construction (fixed point)
                    deg[i] = True
                    cap[i] = pol.degrade_nprobe
                    self.stats.degraded += 1
        return keep, cap, deg, sheds

    def form(
        self, now: float, force: bool = False
    ) -> tuple[Optional[MicroBatch], list[Completion]]:
        """Release the next micro-batch (round-robin across indexes).

        Returns (batch-or-None, sheds) — ``sheds`` are requests dropped at
        formation time because even the degraded path would miss their
        deadline.  ``force`` releases a partial batch regardless of age, in
        strict FIFO order (drain/shutdown path — deterministic regardless of
        grouping mode).
        """
        pick = self._pick_index(now, force)
        if pick is None:
            return None, []
        reqs = self._select(pick, self._pending[pick], now, force)
        keep, cap, deg, shed_reqs = self._admit(reqs, now)
        sheds = []
        for r in shed_reqs:
            self.stats.shed_deadline += 1
            sheds.append(Completion(
                req_id=r.req_id, index=r.index, status="shed",
                ids=None, dists=None, nprobe=0,
                submitted=r.arrival, completed=now,
                reason="deadline", trace_id=r.trace_id,
            ))
        if not keep:
            return None, sheds
        waits = np.asarray([now - r.arrival for r in keep], np.float64)
        self.stats.max_queue_wait_s = max(self.stats.max_queue_wait_s,
                                          float(waits.max()))
        union: Optional[frozenset] = None
        if any(_probe_set(r) for r in keep):
            union = frozenset().union(*[_probe_set(r) for r in keep])
        self.stats.batches += 1
        return MicroBatch(
            index=pick, requests=keep,
            nprobe_cap=cap, degraded=deg, formed_at=now,
            waits=waits, probe_union=union,
        ), sheds
