"""Dynamic micro-batching + deadline-aware admission control (§4.1/§4.2).

TPU serving wants batches (one doorbell per batch, MXU-shaped work), but
traffic arrives one query at a time.  The batcher sits between the
submission queue and the scan pipeline and makes three decisions the paper's
runtime makes in its userspace stack:

* **coalescing** — accumulate single-query arrivals per index and release a
  micro-batch when it is full (``max_batch``) or its head-of-line request
  has waited ``max_wait_s`` (bounded batching delay);
* **admission control / shedding** — a request whose deadline cannot be met
  even by the *fastest* path is completed immediately as ``shed`` (fail fast
  beats queueing doomed work — the paper's overload posture); a request that
  would miss its deadline at the routed LLSP level but could make it at a
  cheaper level is **degraded**: its nprobe is capped (``degrade_nprobe``),
  trading recall for latency instead of dropping the query;
* **fairness** — micro-batches are released round-robin across the node's
  co-resident indexes (§4.2 multi-index hosting), so a hot tenant cannot
  starve a cold one; within an index, FIFO order is preserved.

All decisions are functions of (policy, observed-EWMA service rate, ``now``)
only — replaying a seeded arrival trace against a virtual clock reproduces
the exact shed/degrade/batch sequence, which is what the determinism tests
assert.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from .engine import Completion, SearchRequest


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    max_batch: int = 64            # release when this many are pending
    max_wait_s: float = 0.005      # ... or when head-of-line waited this long
    pad: int = 16                  # micro-batch quantum; keep equal to the
                                   # pipeline's pad_batch (the actual jit
                                   # padding knob) so warmups cover the
                                   # shapes the pipeline really compiles
    shed: str = "degrade"          # "none" | "shed" | "degrade"
    degrade_nprobe: int = 8        # nprobe cap for degraded requests
                                   # (lowest LLSP level bound)
    degrade_speedup: float = 2.0   # assumed service speedup of a degraded req
    overhead_s: float = 1e-3       # fixed per-batch cost (dispatch + merge)
    init_query_s: float = 1e-4     # prior per-query service estimate
    ewma: float = 0.3              # service-estimate smoothing
    update_quantum: int = 64       # max update-lane ops the poller applies
                                   # between search batches — bounds how much
                                   # an update storm can delay the next
                                   # micro-batch (storms back-pressure their
                                   # own SQ instead of starving search)


@dataclasses.dataclass
class MicroBatch:
    index: str
    requests: list                 # list[SearchRequest], FIFO
    nprobe_cap: np.ndarray         # (b,) int32, 0 = uncapped
    degraded: np.ndarray           # (b,) bool
    formed_at: float


@dataclasses.dataclass
class BatcherStats:
    admitted: int = 0
    shed_admission: int = 0        # dead on arrival (deadline unmeetable)
    shed_deadline: int = 0         # dropped at batch formation
    degraded: int = 0
    batches: int = 0


class DynamicBatcher:
    """Per-index pending queues + round-robin micro-batch formation."""

    def __init__(self, policy: BatchPolicy, indexes: list[str]):
        self.policy = policy
        self._pending: dict[str, collections.deque] = {
            name: collections.deque() for name in indexes
        }
        self._rr = 0                       # round-robin cursor over indexes
        self.est_query_s = policy.init_query_s
        self.stats = BatcherStats()

    @property
    def indexes(self) -> list[str]:
        return list(self._pending)

    def add_index(self, name: str) -> None:
        if name in self._pending:
            return
        # copy-on-write: the poller thread iterates self._pending without a
        # lock, so mutate by swapping in a new dict (atomic attribute store)
        # rather than inserting into the one being iterated
        self._pending = {**self._pending, name: collections.deque()}

    def pending(self, index: Optional[str] = None) -> int:
        if index is not None:
            return len(self._pending[index])
        return sum(len(q) for q in self._pending.values())

    def observe(self, batch_size: int, service_s: float) -> None:
        """Fold a measured batch service time into the per-query EWMA."""
        if batch_size <= 0:
            return
        per_q = max(service_s - self.policy.overhead_s, 0.0) / batch_size
        a = self.policy.ewma
        self.est_query_s = (1 - a) * self.est_query_s + a * per_q

    # -- admission ---------------------------------------------------------
    def _min_latency(self, degraded: bool = False) -> float:
        est = self.policy.overhead_s + self.est_query_s
        return est / self.policy.degrade_speedup if degraded else est

    def add(self, req: SearchRequest, now: float) -> Optional[Completion]:
        """Admit a request; returns a shed Completion if it is dead on
        arrival (deadline unmeetable even solo + degraded), else None."""
        if req.index not in self._pending:
            raise KeyError(f"unknown index {req.index!r}")
        if req.deadline is not None and (
            now + self._min_latency(degraded=True) > req.deadline
        ):
            self.stats.shed_admission += 1
            return Completion(
                req_id=req.req_id, index=req.index, status="shed",
                ids=None, dists=None, nprobe=0,
                submitted=req.arrival, completed=now,
            )
        self.stats.admitted += 1
        self._pending[req.index].append(req)
        return None

    # -- batch formation ---------------------------------------------------
    def ready(self, now: float) -> bool:
        """Is some index due for release (full batch or head-of-line aged)?"""
        for q in self._pending.values():
            if len(q) >= self.policy.max_batch:
                return True
            if q and now - q[0].arrival >= self.policy.max_wait_s:
                return True
        return False

    def form(
        self, now: float, force: bool = False
    ) -> tuple[Optional[MicroBatch], list[Completion]]:
        """Release the next micro-batch (round-robin across indexes).

        Returns (batch-or-None, sheds) — ``sheds`` are requests dropped at
        formation time because even the degraded path would miss their
        deadline.  ``force`` releases a partial batch regardless of age
        (drain/shutdown path).
        """
        names = list(self._pending)
        pick = None
        for off in range(len(names)):
            name = names[(self._rr + off) % len(names)]
            q = self._pending[name]
            if not q:
                continue
            due = (len(q) >= self.policy.max_batch
                   or now - q[0].arrival >= self.policy.max_wait_s)
            if force or due:
                pick = name
                self._rr = (names.index(name) + 1) % len(names)
                break
        if pick is None:
            return None, []
        q = self._pending[pick]
        reqs: list[SearchRequest] = []
        sheds: list[Completion] = []
        while q and len(reqs) < self.policy.max_batch:
            reqs.append(q.popleft())
        b = len(reqs)
        est_full = self.policy.overhead_s + self.est_query_s * b
        est_deg = self.policy.overhead_s + (
            self.est_query_s * b / self.policy.degrade_speedup
        )
        cap = np.zeros((b,), np.int32)
        deg = np.zeros((b,), bool)
        keep: list[SearchRequest] = []
        for r in reqs:
            if r.deadline is None or self.policy.shed == "none" \
                    or now + est_full <= r.deadline:
                keep.append(r)
            elif self.policy.shed == "degrade" and now + est_deg <= r.deadline:
                deg[len(keep)] = True
                cap[len(keep)] = self.policy.degrade_nprobe
                keep.append(r)
                self.stats.degraded += 1
            else:
                self.stats.shed_deadline += 1
                sheds.append(Completion(
                    req_id=r.req_id, index=r.index, status="shed",
                    ids=None, dists=None, nprobe=0,
                    submitted=r.arrival, completed=now,
                ))
        if not keep:
            return None, sheds
        b = len(keep)
        self.stats.batches += 1
        return MicroBatch(
            index=pick, requests=keep,
            nprobe_cap=cap[:b], degraded=deg[:b], formed_at=now,
        ), sheds
