"""Double-buffered prefetch pipeline — §4.1's I/O/compute overlap on TPU.

The paper's userspace stack keeps the SSD and the scan engine busy at the
same time: while one batch's posting lists are being scanned, the next
batch's lists are already being read.  The TPU translation: while batch i
runs the fused-topk scan on device, batch i+1's probed-cluster union is
gathered from the host tier and ``device_put`` in flight, so streamed-mode
serving overlaps PCIe with MXU instead of serializing them.

Stage protocol (each stage returns a handle consumed by the next):

  ``plan``     -> centroid scan + LLSP routing/pruning on device, probe set
                  resolved to host (the paper's in-DRAM index walk);
  ``prefetch`` -> host gather of the probed-cluster union + device stream,
                  on a dedicated worker thread (the SQ-side DMA engine);
  ``dispatch`` -> join the gather, launch the fused-topk scan (JAX async
                  dispatch — returns immediately, scan in flight);
  ``harvest``  -> block on the scan outputs, truncate padding.

``run_sequential`` chains the stages strictly (the pre-PR-2 serve loop);
``run_pipelined`` double-buffers them.  Every stage is wall-clock stamped
(:class:`StageTimes`) so :func:`overlap_efficiency` can *measure* how much
of batch i+1's gather/stream interval lands inside batch i's
scan-in-flight interval — the bench asserts overlap from these stamps, not
from throughput alone.

Ordering note: the plan stage of batch i+1 is always enqueued BEFORE batch
i's scan (both in ``run_pipelined`` and in the engine's poller).  The CPU /
TPU backends execute queued computations in order, so planning after the
scan dispatch would serialize the whole pipeline behind the scan.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distance import (
    dedup_topk, merge_candidate_topk, squared_l2, topk_smallest,
)
from repro.core.search import SearchConfig, _auto_ncand, _scan_and_rank, decide_nprobe
from repro.kernels import ops as kops
from repro.storage.host_tier import QuantizedTieredPostings, TieredPostings
from repro.storage.flash_tier import FlashTier


@dataclasses.dataclass
class StageTimes:
    """Wall-clock stamps of one batch through the pipeline (seconds)."""
    size: int = 0                  # true batch size (pre-padding)
    rows: int = 0                  # packed posting rows streamed
    plan_start: float = 0.0
    plan_end: float = 0.0
    gather_start: float = 0.0
    gather_end: float = 0.0        # host union gather materialized
    stream_end: float = 0.0        # packed tensors on device
    scan_dispatch: float = 0.0
    scan_done: float = 0.0
    routed: bool = False           # plan reused an admission-time RoutePlan
    clusters_requested: int = 0    # probe slots across the batch (pre-dedup)
    union_clusters: int = 0        # deduped gather-union size (real clusters)
    union_bytes: int = 0           # payload bytes of the union (measured at
                                   # fetch, excludes pad/sentinel rows) — the
                                   # locality-grouping objective, per batch
    # flash-tier f32 re-rank stage (quantized serving; zeros = no rerank ran)
    rerank_start: float = 0.0
    rerank_end: float = 0.0
    rerank_io_s: float = 0.0       # seconds spent inside flash read bursts
    rerank_rounds: int = 0         # adaptive-stop rounds actually executed
    rerank_cands: int = 0          # candidates exact-scored before the stop
    rerank_stable_stop: bool = False  # True = top-k went stable before the
                                      # candidate list was exhausted
    rerank_round_size: int = 0     # round width this batch actually used
                                   # (== config unless auto_round adapted it)

    @property
    def total(self) -> float:
        end = self.rerank_end if self.rerank_end > 0.0 else self.scan_done
        return end - self.plan_start


@dataclasses.dataclass
class BatchResult:
    ids: np.ndarray                # (b, k) int32
    dists: np.ndarray              # (b, k) float32
    nprobe: np.ndarray             # (b,) int32
    times: StageTimes
    fresh_seq: int = -1            # freshness snapshot this batch scanned
                                   # against (-1 = no fresh view attached)
    partial: Optional[np.ndarray] = None   # (b,) bool — query answered from
                                           # an incomplete shard set (fabric
                                           # degraded mode); None = complete
    partial_reason: str = "no_replica"     # why the shard set was incomplete
                                           # ("no_replica" | "timeout")
    quality: Optional[np.ndarray] = None   # (b,) float32 per-query recall
                                           # proxy (rerank agreement on the
                                           # q8 path, probed-cluster coverage
                                           # on the fabric path); None = the
                                           # serving path produces no proxy
    shards: Optional[np.ndarray] = None    # (b,) int32 primary shard per
                                           # query (fabric only) — lets the
                                           # quality streams label per-shard


@dataclasses.dataclass
class _Plan:
    queries_dev: jax.Array         # (bp, D) padded, on device
    cids: np.ndarray               # (bp, P)
    pmask: np.ndarray              # (bp, P) bool
    nprobe: np.ndarray             # (bp,)
    times: StageTimes
    queries_host: Optional[np.ndarray] = None  # (bp, D) — kept for the
                                               # flash-tier re-rank stage


@dataclasses.dataclass
class _Prep:
    plan: _Plan
    fut: Optional[object]          # gather future (None in resident mode)


@dataclasses.dataclass
class _Inflight:
    out_d: jax.Array
    out_i: jax.Array
    nprobe: np.ndarray
    times: StageTimes
    size: int
    fresh_seq: int = -1
    queries_host: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class RerankConfig:
    """FusionANNS-style adaptive re-rank over the flash tier (2409.16576 §5).

    Candidates arrive sorted by approximate (q8) distance; re-ranking walks
    them in rounds of ``round_size``, reading the f32 rows from the flash
    tier and exact-scoring them.  After each round the current exact top-k
    is compared against the previous round's: once it survives
    ``stable_rounds`` consecutive rounds unchanged (per the whole batch —
    the TPU batch is the scheduling unit), further candidates are provably
    unlikely to displace it and the walk stops.  ``max_rounds`` caps the
    walk (0 = only the candidate width bounds it).

    ``auto_round`` derives the NEXT batch's round width from the stamped
    per-slot flash I/O cost (EWMA over ``rerank_io_s``) so one round's
    read burst targets a fraction of the measured scan window — wide
    enough to amortize read setup, narrow enough that the adaptive stop
    still saves I/O.  Off by default: with it off the configured
    ``round_size`` is used verbatim (parity-tested)."""
    round_size: int = 64
    stable_rounds: int = 1
    max_rounds: int = 0
    auto_round: bool = False


def max_id_replicas(posting_ids) -> int:
    """Largest number of posting slots any single id occupies — the build's
    REALIZED closure replication (<= BuildConfig.max_replicas, but measured
    from the artifact rather than trusted from config).  This is the exact
    bound on how many duplicates of one id can precede the k2-th unique
    candidate, so it is the safe ``dup_bound`` for the oracle's
    pre-selection: a hardcoded bound below it silently drops candidates on
    high-replication builds (the ROADMAP dup_bound=8 hazard)."""
    ids = np.asarray(posting_ids).ravel()
    ids = ids[ids >= 0]
    if ids.size == 0:
        return 1
    return int(np.bincount(ids).max())


@functools.partial(jax.jit, static_argnames=("cfg",))
def _plan_jit(centroids, llsp_params, queries, topk, cfg: SearchConfig):
    d = squared_l2(queries, centroids)
    cdists, cids = topk_smallest(d, min(cfg.nprobe_max, centroids.shape[0]))
    nprobe = decide_nprobe(cfg, llsp_params, queries, topk, cdists)
    return cids.astype(jnp.int32), nprobe


@functools.partial(jax.jit, static_argnames=("cfg", "dup_bound"))
def _scan_streamed_jit(packed, packed_ids, remap, pmask, queries,
                       cfg: SearchConfig, *, dup_bound: int):
    """Candidate-compressed scan over the STREAMED (packed) posting rows.

    use_kernel: the fused Pallas kernel runs directly on the packed tensors
    (remap plays the role of cids).  Oracle path: instead of re-gathering a
    (B, P, L, D) probe tensor from rows we just streamed, distance the whole
    packed payload against the batch with ONE matmul (rows are unique, so
    this does no duplicate work), mask each query to its probed rows via a
    scatter of the remap table, and top-k in the packed domain.  ``dup_bound``
    caps how many closure replicas of one id can precede the k2-th unique
    candidate, so the dedup runs on an O(k2·dup_bound) pre-selection, not on
    all R·L slots.  It is REQUIRED (no default on purpose): the bound must
    cover the build's realized replication or candidates are silently lost —
    PrefetchPipeline derives it from the posting table (max_id_replicas).
    """
    k2 = cfg.n_cand or _auto_ncand(cfg.k)
    if cfg.use_kernel:
        cd, ci = kops.ivf_scan_topk(packed, packed_ids, remap, pmask,
                                    queries, k2=k2)
    else:
        r, l, dim = packed.shape
        b = queries.shape[0]
        d = squared_l2(queries, packed.reshape(r * l, dim))      # (B, R*L)
        member = jnp.zeros((b, r), jnp.int32).at[
            jnp.arange(b)[:, None], remap
        ].add(pmask.astype(jnp.int32))                           # (B, R)
        live = (member > 0)[:, :, None] & (packed_ids >= 0)[None, :, :]
        d = jnp.where(live.reshape(b, r * l), d, jnp.inf)
        ids = jnp.broadcast_to(packed_ids.reshape(1, r * l), (b, r * l))
        m = min(k2 * dup_bound, r * l)
        nd, pos = topk_smallest(d, m)
        cd, ci = dedup_topk(nd, jnp.take_along_axis(ids, pos, axis=-1), k2)
    return merge_candidate_topk(cd, ci, cfg.k)


@functools.partial(jax.jit, static_argnames=("cfg", "dup_bound"))
def _scan_streamed_q8_jit(packed_q8, packed_scale, packed_norm2, packed_cent,
                          packed_ids, remap, pmask, queries,
                          cfg: SearchConfig, *, dup_bound: int):
    """Candidate-compressed scan over STREAMED int8-residual rows — the
    quantized twin of :func:`_scan_streamed_jit`, same packed-domain
    contract (remap-as-cids for the kernel; one int8->f32 matmul + the
    closed-form residual correction for the oracle).  ``packed_cent`` is
    the owning centroid per packed row (the residual distance form needs
    it), gathered by the tier alongside the codes."""
    k2 = cfg.n_cand or _auto_ncand(cfg.k)
    if cfg.use_kernel:
        cd, ci = kops.ivf_scan_q8_topk(
            packed_q8, packed_scale, packed_norm2, packed_cent, packed_ids,
            remap, pmask, queries, k2=k2)
    else:
        r, l, dim = packed_q8.shape
        b = queries.shape[0]
        g8 = packed_q8.astype(jnp.float32)                       # (R, L, D)
        qc = queries[:, None, :] - packed_cent[None, :, :]       # (B, R, D)
        cross = jnp.einsum("brd,rld->brl", qc, g8)               # (B, R, L)
        s = packed_scale[:, 0, 0][None, :, None]                 # (1, R, 1)
        d = (jnp.sum(qc * qc, axis=-1)[:, :, None]
             - 2.0 * s * cross + packed_norm2[None, :, :])
        d = jnp.maximum(d, 0.0).reshape(b, r * l)
        member = jnp.zeros((b, r), jnp.int32).at[
            jnp.arange(b)[:, None], remap
        ].add(pmask.astype(jnp.int32))                           # (B, R)
        live = (member > 0)[:, :, None] & (packed_ids >= 0)[None, :, :]
        d = jnp.where(live.reshape(b, r * l), d, jnp.inf)
        ids = jnp.broadcast_to(packed_ids.reshape(1, r * l), (b, r * l))
        m = min(k2 * dup_bound, r * l)
        nd, pos = topk_smallest(d, m)
        cd, ci = dedup_topk(nd, jnp.take_along_axis(ids, pos, axis=-1), k2)
    return merge_candidate_topk(cd, ci, cfg.k)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _scan_resident_jit(index, queries, cids, pmask, cfg: SearchConfig):
    return _scan_and_rank(index, queries, cids, pmask, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _scan_reference_jit(packed, packed_ids, remap, pmask, queries,
                        cfg: SearchConfig):
    """The PRE-runtime streamed scan (A/B baseline): the PR 1 reference
    oracle on the packed tensors — re-gathers a (B, P, L, D) probe tensor
    from the rows the tier just streamed, exactly what serving on the
    streamed tier looked like before the packed-domain scan existed."""
    from repro.kernels.ref import ivf_scan_topk_ref

    k2 = cfg.n_cand or _auto_ncand(cfg.k)
    cd, ci = ivf_scan_topk_ref(packed, packed_ids, remap, pmask, queries, k2)
    return merge_candidate_topk(cd, ci, cfg.k)


class PrefetchPipeline:
    """Stage-structured streamed/resident serving over one index.

    streamed (``tier`` given): postings live on host in ``tier``; each batch
    streams only its probed-cluster union (§4.1 I/O path).  resident: the
    index is fully device-resident and prefetch is a no-op (all-HBM path) —
    the engine drives both through the same protocol.

    ``pad_batch`` / ``row_bucket`` quantize the jit-visible shapes (padded
    batch size, packed-row count) so long-running daemons compile a bounded
    program set.  ``row_bucket`` trades padding bytes for compile count: a
    coarse bucket wastes a few % of stream bandwidth on zero rows but keeps
    the scan-program set to ~ceil(C / row_bucket) entries — under live
    traffic (union size varies batch to batch) a fine bucket turns into a
    compile storm that dwarfs the padding it saves.
    """

    def __init__(self, index, llsp_params, cfg: SearchConfig,
                 tier: Optional[TieredPostings] = None, *,
                 pad_batch: int = 16, row_bucket: int = 256,
                 dup_bound: Optional[int] = None,
                 fresh_source=None,
                 flash: Optional[FlashTier] = None,
                 rerank: Optional[RerankConfig] = None,
                 quality_proxy: bool = True):
        self.index = index
        self.llsp_params = llsp_params
        self.cfg = cfg
        self.tier = tier
        # flash-tier f32 re-rank (quantized serving): when ``flash`` is set
        # the scan stage keeps its full ~2k candidate width and harvest
        # exact-rescores candidates from the flash tier with adaptive stop.
        self.flash = flash
        self.rerank = rerank if rerank is not None else (
            RerankConfig() if flash is not None else None)
        self.pad_batch = pad_batch
        self.row_bucket = row_bucket
        # freshness hook (lifecycle/ingest.py): a zero-arg callable returning
        # the current FreshSnapshot.  When set, dispatch captures one
        # snapshot per batch and chains the §6.2 delta+tombstone merge onto
        # the in-flight scan — delta brute force folded in, tombstoned main
        # AND delta ids filtered, all before readback.  The scan stage then
        # OVER-FETCHES (k -> n_cand-wide main candidates) so tombstoned
        # slots cannot starve the final top-k — the paper's §6.2 compensation
        # for serving under a growing tombstone set.
        self.fresh_source = fresh_source
        if dup_bound is None:
            # derive the oracle's duplicate pre-selection bound from the
            # build's realized replication (dup_bound=8 hazard: a bound
            # below max replicas drops candidates on max_replicas>8 builds)
            pids = tier.posting_ids if tier is not None else index.posting_ids
            dup_bound = max_id_replicas(pids)
        self.dup_bound = max(int(dup_bound), 1)
        self._gatherer = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="prefetch")
        # rerank reads get their own single-lane SQ (same DMA-engine idiom
        # as the prefetch gatherer): sharing the gatherer would queue batch
        # i's rerank I/O behind batch i+1's union gather and serialize the
        # two stages the overlap argument needs concurrent.
        self._reranker = (ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="rerank")
            if flash is not None else None)
        # per-query recall proxy (quality observability): the overlap of the
        # pre-rerank approximate top-k with the post-rerank exact top-k,
        # stamped on BatchResult.quality.  Free signal on the q8 path — the
        # candidates are already in host memory at harvest.
        self.quality_proxy = bool(quality_proxy)
        # auto_round state (RerankConfig.auto_round): EWMA of the measured
        # per-slot flash read cost and the round width derived from it
        self._io_per_slot: Optional[float] = None
        self._auto_round: Optional[int] = None

    @property
    def _scan_cfg(self) -> SearchConfig:
        """Scan-stage config: with a fresh view attached — or the flash
        re-rank enabled — the main scan keeps n_cand-wide candidates
        (instead of k): the tombstone filter must not starve the final
        merge, and the re-ranker needs the full ~2k candidate set, not the
        already-collapsed top-k."""
        if self.fresh_source is None and self.flash is None:
            return self.cfg
        k2 = self.cfg.n_cand or _auto_ncand(self.cfg.k)
        # pin n_cand too: otherwise the scan derives a fresh auto width
        # from the widened k (~2x wider in-kernel top-k + a redundant merge)
        return dataclasses.replace(self.cfg, k=k2, n_cand=k2)

    @property
    def streamed(self) -> bool:
        return self.tier is not None

    @property
    def quantized(self) -> bool:
        return getattr(self.tier, "quantized", False) \
            or (self.cfg.tier == "q8" and self.tier is None)

    @property
    def tier_kind(self) -> str:
        """"q8" | "f32" first-pass payload (lifecycle reporting)."""
        return "q8" if self.quantized else "f32"

    # -- stages ------------------------------------------------------------
    def _padded_inputs(self, queries, topk):
        """Pad (queries, topk) to the jit batch quantum by repeating the
        last row; returns (q (bp, D), tk (bp,), true b).  The ONE copy of
        the pad idiom: route() and plan() must agree bit-for-bit on it or
        admission-route reuse silently drifts from replanning."""
        q = np.asarray(queries, np.float32)
        tk = np.broadcast_to(np.asarray(topk, np.int32), (len(q),))
        b = len(q)
        bp = -(-b // self.pad_batch) * self.pad_batch
        if bp != b:
            q = np.concatenate([q, np.repeat(q[-1:], bp - b, axis=0)])
            tk = np.concatenate([tk, np.repeat(tk[-1:], bp - b)])
        return q, tk, b

    def route(self, queries: np.ndarray, topk
              ) -> tuple[np.ndarray, np.ndarray]:
        """Admission-time probe routing: the plan stage's centroid scan +
        LLSP level decision ONLY (cheap pre-search features, §4.3), returned
        as host arrays ``(cids (b, P), nprobe (b,))``.

        This is bit-identical to what :meth:`plan` computes (same padded
        inputs, same jit program), so the engine tags each drained request
        with its row and ``plan(routed=...)`` reuses it verbatim — the
        centroid scan moves to admission (where the batcher needs the
        probe signature to group by locality), it is not run twice."""
        q, tk, b = self._padded_inputs(queries, topk)
        cids, nprobe = _plan_jit(self.index.centroids, self.llsp_params,
                                 jnp.asarray(q), jnp.asarray(tk), self.cfg)
        return np.asarray(cids)[:b], np.asarray(nprobe)[:b].astype(np.int32)

    def plan(self, queries: np.ndarray, topk,
             nprobe_cap: Optional[np.ndarray] = None,
             routed: Optional[tuple] = None) -> _Plan:
        """Centroid scan + LLSP pruning; probe set resolved to host arrays.

        ``nprobe_cap`` (b,) int32 caps per-query nprobe (0 = uncapped) —
        the batcher's deadline-degradation hook.  ``routed`` is the
        admission-time probe plan ``(cids (b, P), nprobe (b,))`` from
        :meth:`route`: when given, the centroid scan is skipped and the
        plan stage is pure host bookkeeping (pad + mask)."""
        t = StageTimes(size=len(queries))
        t.plan_start = time.perf_counter()
        q, tk, b = self._padded_inputs(queries, topk)
        bp = len(q)
        qd = jnp.asarray(q)
        if routed is not None:
            rcids, rnp = routed
            rcids = np.asarray(rcids, np.int32)
            cids = np.full((bp, rcids.shape[1]), -1, np.int32)
            cids[:b] = rcids
            nprobe = np.zeros((bp,), np.int32)
            nprobe[:b] = np.asarray(rnp, np.int32)
            t.routed = True
        else:
            cids, nprobe = _plan_jit(self.index.centroids, self.llsp_params,
                                     qd, jnp.asarray(tk), self.cfg)
            cids = np.asarray(cids)
            nprobe = np.asarray(nprobe).copy()
        if nprobe_cap is not None:
            cap = np.zeros((bp,), np.int32)
            cap[:b] = np.asarray(nprobe_cap, np.int32)
            capped = cap > 0
            nprobe[capped] = np.minimum(nprobe[capped], cap[capped])
        nprobe[b:] = 0                     # padding rows probe nothing
        pmask = (np.arange(cids.shape[1])[None, :] < nprobe[:, None]) \
            & (cids >= 0)
        t.plan_end = time.perf_counter()
        return _Plan(qd, cids, pmask, nprobe, t, queries_host=q)

    def _gather(self, plan: _Plan):
        fetched = self.tier.fetch(
            plan.cids, plan.pmask, bucket=self.row_bucket)
        ev = self.tier.stats.events[-1]    # same thread as the fetch: safe
        plan.times.gather_start = ev.gather_start
        plan.times.gather_end = ev.gather_end
        plan.times.stream_end = ev.stream_end
        plan.times.rows = ev.rows
        plan.times.clusters_requested = ev.clusters_requested
        plan.times.union_clusters = ev.clusters_union
        plan.times.union_bytes = ev.union_bytes
        return fetched

    def prefetch(self, plan: _Plan) -> _Prep:
        """Start the host gather + device stream on the worker thread."""
        if not self.streamed:
            return _Prep(plan, None)
        return _Prep(plan, self._gatherer.submit(self._gather, plan))

    def dispatch(self, prep: _Prep, *, reference: bool = False) -> _Inflight:
        """Join the gather, launch the scan (async — returns immediately).

        With a ``fresh_source`` attached, the §6.2 freshness merge is
        chained onto the scan on device: the snapshot is captured HERE (at
        dispatch), so the batch's visibility point is exactly the state a
        concurrent updater had published when the scan launched."""
        plan = prep.plan
        t = plan.times
        if self.streamed:
            fetched = prep.fut.result()
            t.scan_dispatch = time.perf_counter()
            if getattr(self.tier, "quantized", False):
                if reference:
                    raise ValueError(
                        "reference scan is an f32-tier A/B baseline; the "
                        "quantized tier has no pre-runtime twin")
                q8, scale, norm2, cents, pids, remap = fetched
                od, oi = _scan_streamed_q8_jit(
                    q8, scale, norm2, cents, pids, remap,
                    jnp.asarray(plan.pmask), plan.queries_dev,
                    self._scan_cfg, dup_bound=self.dup_bound)
            elif reference:
                packed, pids, remap = fetched
                od, oi = _scan_reference_jit(
                    packed, pids, remap, jnp.asarray(plan.pmask),
                    plan.queries_dev, self._scan_cfg)
            else:
                packed, pids, remap = fetched
                od, oi = _scan_streamed_jit(
                    packed, pids, remap, jnp.asarray(plan.pmask),
                    plan.queries_dev, self._scan_cfg,
                    dup_bound=self.dup_bound)
        else:
            t.scan_dispatch = time.perf_counter()
            od, oi = _scan_resident_jit(
                self.index, plan.queries_dev, jnp.asarray(plan.cids),
                jnp.asarray(plan.pmask), self._scan_cfg)
        seq = -1
        if self.fresh_source is not None:
            snap = self.fresh_source()
            if snap is not None:
                from repro.core.fresh import merge_fresh

                # with the re-ranker on, stay candidate-wide through the
                # fresh merge — the narrowing to k happens after rescoring
                keep = self._scan_cfg.k if self.flash is not None else self.cfg.k
                od, oi = merge_fresh(
                    od, oi, plan.queries_dev, snap.delta_vecs,
                    snap.delta_ids, snap.tombstone, keep)
                seq = snap.seq
        return _Inflight(od, oi, plan.nprobe, t, t.size, fresh_seq=seq,
                         queries_host=plan.queries_host)

    def harvest(self, infl: _Inflight) -> BatchResult:
        """Block on the scan outputs; truncate batch padding.  With the
        flash tier attached, exact-rescore the candidates here — harvest of
        batch i runs while batch i+1's scan is already in flight (the
        poller/pipelined drivers dispatch ahead), so the rerank I/O lands
        inside the next scan window by construction, and the stamps prove
        it per run (:func:`rerank_overlap_efficiency`)."""
        ids = np.asarray(infl.out_i)[: infl.size]
        dists = np.asarray(infl.out_d)[: infl.size]
        infl.times.scan_done = time.perf_counter()
        quality = None
        if self.flash is not None and infl.size > 0:
            # pre-rerank approximate top-k (candidates arrive ascending by
            # q8 distance) — captured before rescoring reorders them, so the
            # rerank-agreement proxy costs one (b, k) copy on the hot path
            pre_top = ids[:, : self.cfg.k].copy() if self.quality_proxy \
                else None
            dists, ids = self._rerank(
                infl.queries_host[: infl.size], dists, ids, infl.times)
            if pre_top is not None:
                from repro.obs.quality import recall_proxy

                quality = recall_proxy(pre_top, ids, self.cfg.k)
        return BatchResult(ids, dists, infl.nprobe[: infl.size].copy(),
                           infl.times, fresh_seq=infl.fresh_seq,
                           quality=quality)

    def _rerank(self, queries: np.ndarray, cand_d: np.ndarray,
                cand_i: np.ndarray, t: StageTimes
                ) -> tuple[np.ndarray, np.ndarray]:
        """Flash-tier exact re-rank with FusionANNS adaptive stop.

        Candidates arrive ascending by q8-approx distance.  Rounds of
        ``rerank.round_size`` columns are exact-scored from the flash tier;
        each round's read is issued on the rerank SQ one round AHEAD of the
        scoring (double-buffered), so flash I/O overlaps the host math the
        same way the prefetch gather overlaps the device scan.  Ids outside
        the flash tier (fresh-delta candidates, already exact) and padding
        (-1) keep their incoming distance.  Stops once the batch's exact
        top-k survives ``stable_rounds`` rounds unchanged."""
        rc = self.rerank
        k = self.cfg.k
        b, n = cand_i.shape
        t.rerank_start = time.perf_counter()
        exact = np.array(cand_d, np.float32, copy=True)
        step = max(int(rc.round_size), 1)
        if rc.auto_round and self._auto_round is not None:
            step = self._auto_round
        t.rerank_round_size = step
        n_rounds = -(-n // step)
        if rc.max_rounds > 0:
            n_rounds = min(n_rounds, int(rc.max_rounds))
        futs: dict[int, object] = {}

        def _submit(r):
            if r < n_rounds and r not in futs:
                futs[r] = self._reranker.submit(
                    self.flash.read, cand_i[:, r * step:(r + 1) * step])

        prev_top = None
        stable = 0
        rounds = 0
        hi = 0
        _submit(0)
        for r in range(n_rounds):
            _submit(r + 1)                 # double-buffer the next read
            uids, rows = futs.pop(r).result()
            ev = self.flash.stats.events[-1]
            t.rerank_io_s += ev.end - ev.start
            lo, hi = r * step, min(n, (r + 1) * step)
            cols = cand_i[:, lo:hi]
            in_flash = (cols >= 0) & (cols < self.flash.n)
            if uids.size:
                pos = np.searchsorted(uids, np.clip(cols, 0, None))
                pos = np.clip(pos, 0, uids.size - 1)
                hit = in_flash & (uids[pos] == np.clip(cols, 0, None))
                vecs = rows[pos]                       # (b, w, D)
                d = np.sum((queries[:, None, :] - vecs) ** 2, axis=-1)
                exact[:, lo:hi] = np.where(hit, d, exact[:, lo:hi])
            rounds = r + 1
            # adaptive stop: current exact top-k over the scored prefix
            if hi >= k:
                part = np.argpartition(exact[:, :hi], k - 1, axis=1)[:, :k]
                rowd = np.take_along_axis(exact[:, :hi], part, axis=1)
                order = np.argsort(rowd, axis=1, kind="stable")
                sel = np.take_along_axis(part, order, axis=1)
                top = np.take_along_axis(cand_i[:, :hi], sel, axis=1)
                if prev_top is not None and np.array_equal(top, prev_top):
                    stable += 1
                    if stable >= max(int(rc.stable_rounds), 1):
                        t.rerank_stable_stop = hi < n
                        break
                else:
                    stable = 0
                prev_top = top
        for f in futs.values():            # a speculative read may be queued
            f.cancel()
        # final top-k: exact over the rescored prefix (unvisited tail keeps
        # approx order and, by the stop rule, cannot displace the stable set)
        hi = max(hi, min(n, k))
        part = np.argpartition(exact[:, :hi], min(k, hi) - 1, axis=1)[:, :k]
        rowd = np.take_along_axis(exact[:, :hi], part, axis=1)
        order = np.argsort(rowd, axis=1, kind="stable")
        sel = np.take_along_axis(part, order, axis=1)
        out_d = np.take_along_axis(exact[:, :hi], sel, axis=1)
        out_i = np.take_along_axis(cand_i[:, :hi], sel, axis=1)
        t.rerank_rounds = rounds
        t.rerank_cands = int(hi)
        t.rerank_end = time.perf_counter()
        if rc.auto_round and hi > 0 and t.rerank_io_s > 0.0:
            # learn the per-slot flash read cost from this batch's stamps
            # and retarget the NEXT batch's round width so one round's read
            # burst is ~1/4 of the measured scan window: rounds stay small
            # enough for the adaptive stop to save I/O, wide enough to
            # amortize per-read setup
            per_slot = t.rerank_io_s / float(b * hi)
            self._io_per_slot = per_slot if self._io_per_slot is None \
                else 0.7 * self._io_per_slot + 0.3 * per_slot
            scan_win = max(t.scan_done - t.scan_dispatch, 1e-5)
            want = (scan_win / 4.0) / max(self._io_per_slot * b, 1e-12)
            self._auto_round = int(np.clip(want, 16, max(n, 16)))
        return out_d, out_i

    def warmup(self, batch_sizes=(16, 32), max_rows: Optional[int] = None
               ) -> int:
        """Pre-compile every (padded batch, row-bucket) scan/plan shape a
        live engine can hit, so traffic never pays a compile.  A cold
        compile (~0.5-1 s) landing mid-trace queues hundreds of arrivals
        past their deadline and the admission controller sheds them — the
        warmup turns that cliff into a one-time startup cost.  Returns the
        number of programs compiled."""
        if not self.streamed:
            for b in batch_sizes:
                bp = -(-b // self.pad_batch) * self.pad_batch
                self.serve_batch(np.zeros((bp, self.index.dim), np.float32),
                                 10)
            return len(batch_sizes) + self._warm_fresh(batch_sizes)
        quant = getattr(self.tier, "quantized", False)
        payload = self.tier.q8 if quant else self.tier.postings
        c, l, d = payload.shape
        max_rows = max_rows or c + 1
        max_rows = -(-max_rows // self.row_bucket) * self.row_bucket
        n = 0
        for b in batch_sizes:
            bp = -(-b // self.pad_batch) * self.pad_batch
            q = np.zeros((bp, d), np.float32)
            qd = jnp.asarray(q)
            _plan_jit(self.index.centroids, self.llsp_params, qd,
                      jnp.full((bp,), 10, jnp.int32), self.cfg)
            p = min(self.cfg.nprobe_max, c)
            for rows in range(self.row_bucket, max_rows + 1, self.row_bucket):
                if quant:
                    _scan_streamed_q8_jit(
                        jnp.zeros((rows, l, d), jnp.int8),
                        jnp.ones((rows, 1, 1), jnp.float32),
                        jnp.zeros((rows, l), jnp.float32),
                        jnp.zeros((rows, d), jnp.float32),
                        jnp.full((rows, l), -1, jnp.int32),
                        jnp.zeros((bp, p), jnp.int32),
                        jnp.zeros((bp, p), bool), qd, self._scan_cfg,
                        dup_bound=self.dup_bound)
                else:
                    _scan_streamed_jit(
                        jnp.zeros((rows, l, d), jnp.float32),
                        jnp.full((rows, l), -1, jnp.int32),
                        jnp.zeros((bp, p), jnp.int32),
                        jnp.zeros((bp, p), bool), qd, self._scan_cfg,
                        dup_bound=self.dup_bound)
                n += 1
        return n + self._warm_fresh(batch_sizes)

    def _warm_fresh(self, batch_sizes) -> int:
        """Pre-compile the freshness-merge program per padded batch size
        (snapshot array shapes are epoch-constant, so one program each)."""
        if self.fresh_source is None:
            return 0
        snap = self.fresh_source()
        if snap is None:
            return 0
        from repro.core.fresh import merge_fresh

        kw = self._scan_cfg.k              # over-fetched main-candidate width
        n = 0
        for b in batch_sizes:
            bp = -(-b // self.pad_batch) * self.pad_batch
            merge_fresh(
                jnp.full((bp, kw), jnp.inf, jnp.float32),
                jnp.full((bp, kw), -1, jnp.int32),
                jnp.zeros((bp, self.index.dim), jnp.float32),
                snap.delta_vecs, snap.delta_ids, snap.tombstone, self.cfg.k)
            n += 1
        return n

    # -- convenience drivers ----------------------------------------------
    def serve_batch(self, queries, topk,
                    nprobe_cap: Optional[np.ndarray] = None) -> BatchResult:
        plan = self.plan(queries, topk, nprobe_cap=nprobe_cap)
        return self.harvest(self.dispatch(self.prefetch(plan)))

    def run_sequential(self, batches, *, reference: bool = False
                       ) -> list[BatchResult]:
        """Strictly serial stage chain per batch — the A/B baseline: host
        idle during scan, device idle during gather.  ``reference=True``
        additionally swaps in the pre-runtime reference scan (the full
        pre-PR-2 loop); False isolates the overlap effect alone (identical
        scan program, only the stage ordering differs vs run_pipelined)."""
        out = []
        for queries, topk in batches:
            plan = self.plan(queries, topk)
            prep = self.prefetch(plan)
            if prep.fut is not None:
                prep.fut.result()          # block: no overlap, by design
            infl = self.dispatch(prep, reference=reference)
            jax.block_until_ready(infl.out_d)
            out.append(self.harvest(infl))
        return out

    def run_pipelined(self, batches, *, depth: int = 1) -> list[BatchResult]:
        """N-deep pipelining: the next batch is planned before the prepared
        batch's scan is dispatched, then gathered/streamed while up to
        ``depth`` scans are in flight.  depth=1 is the PR 2 double buffer;
        deeper windows keep the device fed when scan ≪ gather (the harvest
        of batch i is deferred until the window is full, so batch i+1's —
        and i+2's — scans launch behind it without blocking on readback)."""
        batches = list(batches)
        if not batches:
            return []
        depth = max(int(depth), 1)
        out: list[BatchResult] = []
        inflight: collections.deque = collections.deque()
        prep = self.prefetch(self.plan(*batches[0]))
        i = 1
        while prep is not None or inflight:
            if prep is not None and len(inflight) < depth:
                nxt = self.plan(*batches[i]) if i < len(batches) else None
                i += 1
                inflight.append(self.dispatch(prep))
                prep = self.prefetch(nxt) if nxt is not None else None
            else:
                out.append(self.harvest(inflight.popleft()))
        return out


def inflight_depth(times: list[StageTimes]) -> int:
    """Peak number of batches simultaneously in flight on the device stream,
    measured from the stage stamps: a batch is in flight from its scan
    dispatch to its harvest.  The N-deep-window evidence is this value
    (>= 2 means a second scan was dispatched before the first's readback),
    not an inference from throughput."""
    events: list[tuple[float, int]] = []
    for t in times:
        if t.scan_done > t.scan_dispatch:
            events.append((t.scan_dispatch, 1))
            events.append((t.scan_done, -1))
    events.sort()                  # (-1 sorts before +1 at equal stamps:
    cur = peak = 0                 # touching intervals don't count as deep)
    for _, d in events:
        cur += d
        peak = max(peak, cur)
    return peak


def overlap_efficiency(times: list[StageTimes]) -> float:
    """Fraction of gather+stream seconds hidden under the previous batch's
    scan-in-flight window (0 = fully serial, ~1 = fully hidden)."""
    tot = 0.0
    hidden = 0.0
    for prev, cur in zip(times, times[1:]):
        g0, g1 = cur.gather_start, cur.stream_end
        if g1 <= g0:
            continue
        tot += g1 - g0
        s0, s1 = prev.scan_dispatch, prev.scan_done
        hidden += max(0.0, min(g1, s1) - max(g0, s0))
    return hidden / tot if tot > 0 else 0.0


def stage_spans(t: StageTimes) -> list[tuple[str, float, float]]:
    """(name, t0, t1) trace spans for one batch, from the stamps StageTimes
    already holds — the obs layer emits these with zero extra clock reads.
    Unstamped stages (e.g. gather on the fabric path, where stream_end ==
    gather_end) drop out."""
    spans = [("plan", t.plan_start, t.plan_end),
             ("gather", t.gather_start, t.gather_end),
             ("stream", t.gather_end, t.stream_end),
             ("scan", t.scan_dispatch, t.scan_done),
             ("rerank", t.rerank_start, t.rerank_end)]
    return [(n, a, b) for n, a, b in spans if b > a > 0.0]


def rerank_overlap_efficiency(times: list[StageTimes]) -> float:
    """Fraction of batch i's re-rank seconds landing inside batch i+1's
    scan-in-flight window — the quantized-serving twin of
    :func:`overlap_efficiency`.  The poller dispatches batch i+1's scan
    before harvesting batch i, so the flash reads + exact rescoring of i
    run while i+1 occupies the device; this measures that claim from the
    stamps instead of asserting it.  Batches that didn't re-rank drop out;
    returns 0.0 when nothing re-ranked or nothing followed."""
    tot = 0.0
    hidden = 0.0
    for cur, nxt in zip(times, times[1:]):
        r0, r1 = cur.rerank_start, cur.rerank_end
        if r1 <= r0:
            continue
        tot += r1 - r0
        s0, s1 = nxt.scan_dispatch, nxt.scan_done
        hidden += max(0.0, min(r1, s1) - max(r0, s0))
    return hidden / tot if tot > 0 else 0.0


def _vectors_from_postings(index) -> np.ndarray:
    """Reconstruct the (N, D) f32 corpus from the posting payload: every
    live slot carries its vector, closure replicas carry identical copies,
    so a scatter by global id is exact.  This is what lets the lifecycle
    rebuild path mint a flash tier without threading the raw corpus through
    every delta build."""
    pids = np.asarray(index.posting_ids)
    payload = np.asarray(index.postings, np.float32)
    dim = payload.shape[-1]
    flat_ids = pids.reshape(-1)
    live = flat_ids >= 0
    n = int(flat_ids[live].max()) + 1 if live.any() else 0
    out = np.zeros((n, dim), np.float32)
    out[flat_ids[live]] = payload.reshape(-1, dim)[live]
    return out


def make_quantized_pipeline(index, llsp_params, cfg: SearchConfig, *,
                            epoch: int = 0, arena=None, flash_path=None,
                            name: str = "helmsman", vectors=None,
                            rerank: Optional[RerankConfig] = None,
                            with_flash: bool = True,
                            fresh_source=None, **pipe_kw) -> PrefetchPipeline:
    """Build the quantized-default serving pipeline for one index version:
    q8 hot tier (dead slots masked out of the scale), f32 corpus demoted to
    the mmap flash tier, adaptive re-rank on.  Used by launch/serve.py at
    deploy AND as the lifecycle ``make_pipeline`` hook so delta rebuilds
    emit quantized shards — the tier choice survives a rebuild+swap.

    ``vectors`` (N, D) is the id-addressed f32 corpus; when omitted it is
    reconstructed from the posting payload (exact — pads are masked).
    ``with_flash=False`` serves raw q8 distances with no re-rank tier
    (the --no-rerank A/B arm).
    """
    from repro.core.quantize import quantize_postings
    from repro.storage.host_tier import QuantizedTieredPostings

    qp = quantize_postings(index.postings, index.centroids,
                           index.posting_ids)
    tier = QuantizedTieredPostings(
        np.asarray(qp.q8), np.asarray(qp.scale), np.asarray(qp.norm2),
        np.asarray(index.centroids), np.asarray(index.posting_ids),
        epoch=epoch)
    flash = None
    if with_flash:
        if vectors is None:
            vectors = _vectors_from_postings(index)
        flash = FlashTier(vectors, flash_path, arena=arena, name=name,
                          epoch=epoch)
    cfg = dataclasses.replace(cfg, tier="q8")
    return PrefetchPipeline(index, llsp_params, cfg, tier,
                            flash=flash, rerank=rerank,
                            fresh_source=fresh_source, **pipe_kw)


def latency_percentiles(lat_s: list[float]) -> dict:
    if not lat_s:
        return {"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
    a = np.asarray(lat_s) * 1e3
    return {
        "p50_ms": float(np.percentile(a, 50)),
        "p99_ms": float(np.percentile(a, 99)),
        "mean_ms": float(a.mean()),
    }
