"""Queue-pair serving engine — the userspace SQ/CQ stack of §4.1, on host.

The paper replaces the kernel block stack with userspace submission /
completion queue pairs: producers append commands to a bounded SQ, ring a
doorbell, and a polling thread drains completions without syscalls or
per-request wakeups.  The TPU-serving analogue implemented here:

* :class:`QueuePair` — a bounded submission queue of :class:`SearchRequest`
  plus a completion queue of :class:`Completion`.  ``submit`` is the
  doorbell (condition notify); a full SQ is back-pressure and fails fast
  (or blocks, caller's choice) instead of growing an unbounded backlog.
* :class:`ServeEngine` — the poller: drains the SQ into the
  :class:`~repro.runtime.batcher.DynamicBatcher`, releases micro-batches
  into a :class:`~repro.runtime.pipeline.PrefetchPipeline`, and pushes
  completions.  Its serving loop keeps one batch *scanning on device* while
  the next batch is *planned and its clusters gathered on host* — the
  prefetch-overlap that makes streamed serving bandwidth-bound instead of
  latency-bound (measured, not asserted: see StageTimes/overlap_efficiency
  in runtime/pipeline.py).

Determinism: everything time-dependent takes an injectable ``clock``; tests
drive :meth:`ServeEngine.step` with a virtual clock, the daemon uses
:meth:`ServeEngine.start`'s real poller thread.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

import numpy as np


@dataclasses.dataclass
class SearchRequest:
    """One query submitted to the SQ (the paper's NVMe-command analogue)."""
    req_id: int
    index: str
    query: np.ndarray               # (D,) float32
    topk: int
    deadline: Optional[float]       # absolute clock time, None = best-effort
    arrival: float = 0.0


@dataclasses.dataclass
class Completion:
    """CQ entry.  status: "ok" | "degraded" | "shed"."""
    req_id: int
    index: str
    status: str
    ids: Optional[np.ndarray]       # (k,) int32 (None when shed)
    dists: Optional[np.ndarray]     # (k,) float32
    nprobe: int
    submitted: float
    completed: float

    @property
    def latency(self) -> float:
        return self.completed - self.submitted


class QueuePair:
    """Bounded SQ + CQ with doorbell semantics (thread-safe)."""

    def __init__(self, sq_depth: int = 1024):
        self.sq_depth = sq_depth
        self._sq: collections.deque = collections.deque()
        self._cq: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._doorbell = threading.Condition(self._lock)   # SQ became nonempty
        self._not_full = threading.Condition(self._lock)   # SQ drained
        self._cq_ready = threading.Condition(self._lock)   # CQ grew

    def submit(self, req: SearchRequest, block: bool = False,
               timeout: Optional[float] = None) -> bool:
        """Append to the SQ and ring the doorbell.  Returns False when the
        queue is full (back-pressure) and ``block`` is False or timed out."""
        with self._lock:
            if len(self._sq) >= self.sq_depth:
                if not block:
                    return False
                ok = self._not_full.wait_for(
                    lambda: len(self._sq) < self.sq_depth, timeout)
                if not ok:
                    return False
            self._sq.append(req)
            self._doorbell.notify_all()
            return True

    def sq_len(self) -> int:
        with self._lock:
            return len(self._sq)

    def cq_len(self) -> int:
        with self._lock:
            return len(self._cq)

    def pop_submissions(self, max_n: int = 0) -> list[SearchRequest]:
        """Poller side: drain up to max_n (0 = all) submissions FIFO."""
        with self._lock:
            n = len(self._sq) if max_n <= 0 else min(max_n, len(self._sq))
            out = [self._sq.popleft() for _ in range(n)]
            if out:
                self._not_full.notify_all()
            return out

    def wait_submissions(self, timeout: Optional[float] = None) -> bool:
        """Poller side: sleep until the doorbell rings (or timeout)."""
        with self._lock:
            return self._doorbell.wait_for(lambda: len(self._sq) > 0, timeout)

    def complete(self, comps: list[Completion]) -> None:
        with self._lock:
            self._cq.extend(comps)
            if comps:
                self._cq_ready.notify_all()

    def poll(self, max_n: int = 0) -> list[Completion]:
        """Consumer side: drain up to max_n (0 = all) completions FIFO."""
        with self._lock:
            n = len(self._cq) if max_n <= 0 else min(max_n, len(self._cq))
            return [self._cq.popleft() for _ in range(n)]

    def wait_completions(self, n: int = 1,
                         timeout: Optional[float] = None) -> bool:
        with self._lock:
            return self._cq_ready.wait_for(lambda: len(self._cq) >= n, timeout)


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    rejected: int = 0               # SQ-full back-pressure
    completed: int = 0
    shed: int = 0
    degraded: int = 0
    batches: int = 0
    service_s: float = 0.0          # summed batch service time


class ServeEngine:
    """SQ -> batcher -> prefetch pipeline -> CQ, with one-deep overlap.

    ``pipelines`` maps index name -> PrefetchPipeline (the §4.2 multi-index
    node).  The engine itself is pipeline-agnostic: it only needs the
    ``plan / prefetch / dispatch / harvest`` stage protocol.
    """

    def __init__(self, pipelines: dict, batcher, qp: Optional[QueuePair] = None,
                 clock=time.monotonic, update_lanes: Optional[dict] = None):
        self.pipelines = dict(pipelines)
        self.batcher = batcher
        self.qp = qp or QueuePair()
        self.clock = clock
        self.stats = EngineStats()
        self._req_ids = iter(range(1 << 62))
        self._swap_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._drain_on_stop = True
        # index lifecycle hooks (repro.lifecycle): the update lane(s) the
        # poller pumps between search batches, and the version manager that
        # routes batches to epochs (set by VersionManager.bind)
        self.update_lanes: dict = dict(update_lanes or {})
        self.versions = None

    # -- client side -------------------------------------------------------
    def submit(self, query: np.ndarray, topk: int, index: Optional[str] = None,
               deadline_s: Optional[float] = None, block: bool = False) -> int:
        """Submit one query; returns req_id, or -1 on SQ back-pressure."""
        now = self.clock()
        if index is None:
            index = next(iter(self.pipelines))
        elif index not in self.pipelines:
            # fail on the CLIENT thread: an unknown index reaching the
            # poller would kill the serve loop for everyone
            raise KeyError(f"unknown index {index!r}")
        req = SearchRequest(
            req_id=next(self._req_ids), index=index,
            query=np.asarray(query, np.float32), topk=int(topk),
            deadline=None if deadline_s is None else now + deadline_s,
            arrival=now,
        )
        if not self.qp.submit(req, block=block):
            self.stats.rejected += 1
            return -1
        self.stats.submitted += 1
        return req.req_id

    # -- index lifecycle (rebuild/swap flow of launch/serve.py) ------------
    def swap_pipeline(self, name: str, pipeline) -> None:
        """Atomically swap in a freshly built index (daily-rebuild flow)."""
        with self._swap_lock:
            self.pipelines[name] = pipeline
            self.batcher.add_index(name)

    def add_update_lane(self, name: str, lane) -> None:
        """Attach an update lane (lifecycle/ingest.py) for ``name``: the
        poller drains it between search batches, update_quantum at a time."""
        self.update_lanes = {**self.update_lanes, name: lane}

    def _pipeline(self, name: str):
        with self._swap_lock:
            return self.pipelines[name]

    def _pump_updates(self, now: float, drain: bool = False) -> int:
        """Apply a bounded quantum of pending update ops per lane (the
        interleave point: called between search batches, never inside one).
        ``drain=True`` flushes everything (shutdown path)."""
        budget = 0 if drain else self.batcher.policy.update_quantum
        n = 0
        for lane in self.update_lanes.values():
            n += lane.pump(now, budget)
        return n

    # -- poller ------------------------------------------------------------
    def _drain_sq(self, now: float) -> None:
        sheds = []
        for req in self.qp.pop_submissions():
            c = self.batcher.add(req, now)
            if c is not None:
                sheds.append(c)
        if sheds:
            self.stats.shed += len(sheds)
            self.stats.completed += len(sheds)
            self.qp.complete(sheds)

    def _complete_batch(self, mb, result, done: float, epoch=None) -> None:
        comps = []
        for i, req in enumerate(mb.requests):
            status = "degraded" if mb.degraded[i] else "ok"
            comps.append(Completion(
                req_id=req.req_id, index=req.index, status=status,
                ids=result.ids[i], dists=result.dists[i],
                nprobe=int(result.nprobe[i]),
                submitted=req.arrival, completed=done,
            ))
        self.stats.degraded += int(mb.degraded.sum())
        self.stats.completed += len(comps)
        self.stats.batches += 1
        if epoch is not None:
            self.versions.harvested(epoch)
        if result.fresh_seq >= 0:
            lane = self.update_lanes.get(mb.index)
            if lane is not None:
                # visibility stamp: every update op covered by this batch's
                # snapshot now has a search response that could contain it
                lane.mark_visible(result.fresh_seq, done)
        # marginal batch cost = its own stage durations, NOT wall span from
        # plan_start (in the pipelined steady state that span also covers
        # the previous batch's in-flight scan and would inflate the EWMA
        # ~2x, making admission control shed meetable requests)
        t = result.times
        service = (t.plan_end - t.plan_start) + (t.scan_done - t.scan_dispatch)
        self.stats.service_s += service
        self.batcher.observe(len(mb.requests), service)
        self.qp.complete(comps)

    def _form_and_plan(self, now: float, force: bool = False):
        """Form the next micro-batch and run its plan stage (device idle
        here by construction — before the current batch's scan dispatch).

        Epoch routing happens HERE: the batch takes an in-flight reference
        on the current epoch and carries it to harvest, so a concurrent
        swap cannot re-route (or early-retire) a batch mid-flight."""
        mb, sheds = self.batcher.form(now, force=force)
        if sheds:
            self.stats.shed += len(sheds)
            self.stats.completed += len(sheds)
            self.qp.complete(sheds)
        if mb is None:
            return None
        epoch = None
        if self.versions is not None:
            epoch = self.versions.route(mb.index)
        pipe = epoch.pipeline if epoch is not None else self._pipeline(mb.index)
        queries = np.stack([r.query for r in mb.requests])
        topk = np.asarray([r.topk for r in mb.requests], np.int32)
        plan = pipe.plan(queries, topk, nprobe_cap=mb.nprobe_cap)
        return mb, pipe, plan, epoch

    def step(self, now: Optional[float] = None, force: bool = True) -> int:
        """Synchronous single-batch step (tests / virtual clock): drain the
        SQ, form one micro-batch, serve it end-to-end.  Returns the number
        of completions produced."""
        now = self.clock() if now is None else now
        before = self.stats.completed
        self._drain_sq(now)
        self._pump_updates(now)
        planned = self._form_and_plan(now, force=force)
        if planned is not None:
            mb, pipe, plan, epoch = planned
            result = pipe.harvest(pipe.dispatch(pipe.prefetch(plan)))
            self._complete_batch(mb, result,
                                 self.clock() if now is None else now,
                                 epoch=epoch)
        return self.stats.completed - before

    def _serve_loop(self) -> None:
        """Overlapped poller: while batch i scans on device, batch i+1 is
        formed, planned, and its cluster union gathered/streamed on host.

        The plan stage of batch i+1 runs BEFORE batch i's scan dispatch so
        its (small) device work is not queued behind the (large) scan on the
        backend's in-order execution stream — this ordering is what makes
        the host gather actually land inside the scan-in-flight window.
        """
        prep = None                    # (mb, pipe, prefetch-handle, epoch)
        while not self._stop.is_set():
            now = self.clock()
            self._drain_sq(now)
            # update interleave point: BETWEEN batches, a bounded quantum —
            # an update storm back-pressures its own SQ, search cadence holds
            self._pump_updates(now)
            if prep is None:
                planned = self._form_and_plan(now)
                if planned is None:
                    self.qp.wait_submissions(
                        timeout=self.batcher.policy.max_wait_s)
                    continue
                mb, pipe, plan, epoch = planned
                prep = (mb, pipe, pipe.prefetch(plan), epoch)
                continue               # give the SQ one more drain pass
            # commit the prepared batch: plan the NEXT batch first (device
            # idle), dispatch scan, then gather the next batch under it.
            nxt = self._form_and_plan(now)
            mb, pipe, h, epoch = prep
            infl = pipe.dispatch(h)
            prep = None
            if nxt is not None:
                mb2, pipe2, plan2, epoch2 = nxt
                prep = (mb2, pipe2, pipe2.prefetch(plan2), epoch2)
            result = pipe.harvest(infl)
            self._complete_batch(mb, result, self.clock(), epoch=epoch)
        # drain: finish anything still prepared or pending
        if prep is not None:
            mb, pipe, h, epoch = prep
            result = pipe.harvest(pipe.dispatch(h))
            self._complete_batch(mb, result, self.clock(), epoch=epoch)
        while self._drain_on_stop:
            now = self.clock()
            self._drain_sq(now)
            self._pump_updates(now, drain=True)
            planned = self._form_and_plan(now, force=True)
            if planned is None:
                if self.batcher.pending() > 0:
                    continue          # a fully-shed batch is not "drained"
                break
            mb, pipe, plan, epoch = planned
            result = pipe.harvest(pipe.dispatch(pipe.prefetch(plan)))
            self._complete_batch(mb, result, self.clock(), epoch=epoch)

    def start(self) -> None:
        assert self._thread is None, "engine already started"
        self._stop.clear()
        self._drain_on_stop = True
        self._thread = threading.Thread(
            target=self._serve_loop, name="serve-poller", daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the poller; by default finishes every admitted request."""
        if self._thread is None:
            return
        self._drain_on_stop = drain
        self._stop.set()
        self._thread.join()
        self._thread = None
