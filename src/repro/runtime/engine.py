"""Queue-pair serving engine — the userspace SQ/CQ stack of §4.1, on host.

The paper replaces the kernel block stack with userspace submission /
completion queue pairs: producers append commands to a bounded SQ, ring a
doorbell, and a polling thread drains completions without syscalls or
per-request wakeups.  The TPU-serving analogue implemented here:

* :class:`QueuePair` — a bounded submission queue of :class:`SearchRequest`
  plus a completion queue of :class:`Completion`.  ``submit`` is the
  doorbell (condition notify); a full SQ is back-pressure and fails fast
  (or blocks, caller's choice) instead of growing an unbounded backlog.
* :class:`ServeEngine` — the poller: drains the SQ into the
  :class:`~repro.runtime.batcher.DynamicBatcher`, releases micro-batches
  into a :class:`~repro.runtime.pipeline.PrefetchPipeline`, and pushes
  completions.  Its serving loop keeps one batch *scanning on device* while
  the next batch is *planned and its clusters gathered on host* — the
  prefetch-overlap that makes streamed serving bandwidth-bound instead of
  latency-bound (measured, not asserted: see StageTimes/overlap_efficiency
  in runtime/pipeline.py).

Determinism: everything time-dependent takes an injectable ``clock``; tests
drive :meth:`ServeEngine.step` with a virtual clock, the daemon uses
:meth:`ServeEngine.start`'s real poller thread.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.obs import Observability
from repro.runtime.pipeline import stage_spans


@dataclasses.dataclass
class RoutePlan:
    """Admission-time probe routing for one request (§4.3-compatible: only
    pre-search features — the centroid scan + LLSP level decision the plan
    stage would run anyway, computed once when the request leaves the SQ).

    ``probe_set`` is the locality signature the batcher groups on;
    ``source`` tags the pipeline whose centroids produced the route, so a
    batch formed after an epoch swap detects the stale route and replans
    instead of scanning the new index with the old cluster ids."""
    cids: np.ndarray                # (P,) int32 probed clusters, -1 padded
    nprobe: int
    probe_set: frozenset            # {cluster id} — the grouping signature
    source: object                  # pipeline that routed (staleness tag)
    bits: Optional[np.ndarray] = None   # (max probed id + 1,) bool cache,
                                        # built lazily by the batcher so
                                        # formation never redoes the
                                        # set -> bitset conversion per pool


@dataclasses.dataclass
class SearchRequest:
    """One query submitted to the SQ (the paper's NVMe-command analogue)."""
    req_id: int
    index: str
    query: np.ndarray               # (D,) float32
    topk: int
    deadline: Optional[float]       # absolute clock time, None = best-effort
    arrival: float = 0.0
    route: Optional[RoutePlan] = None   # set by the poller at SQ drain
    trace_id: int = 0               # obs identity minted at submit
                                    # (0 = unsampled/untraced)


@dataclasses.dataclass
class Completion:
    """CQ entry.  status: "ok" | "degraded" | "shed" | "partial" | "failed".

    "partial": answered from an incomplete shard set (the fabric's
    graceful-degrade path — ids/dists are valid but may miss candidates
    from lost clusters).  "failed": the serving path itself errored; the
    request is completed (never abandoned) with no payload.

    ``reason`` says WHY for every non-"ok" status ("deadline", "drain",
    "no_replica", "timeout", "plan_error", "prefetch_error",
    "dispatch_error", "harvest_error", "crash_drain") — the label the
    shed/degrade/partial counters break down by.  New fields are appended
    with defaults so positional construction stays valid."""
    req_id: int
    index: str
    status: str
    ids: Optional[np.ndarray]       # (k,) int32 (None when shed)
    dists: Optional[np.ndarray]     # (k,) float32
    nprobe: int
    submitted: float
    completed: float
    reason: str = ""                # why, for every non-"ok" status
    trace_id: int = 0
    quality: float = -1.0           # per-query recall proxy (rerank
                                    # agreement / fabric coverage);
                                    # -1 = the path produced no proxy

    @property
    def latency(self) -> float:
        return self.completed - self.submitted


class QueuePair:
    """Bounded SQ + CQ with doorbell semantics (thread-safe)."""

    def __init__(self, sq_depth: int = 1024):
        self.sq_depth = sq_depth
        self._sq: collections.deque = collections.deque()
        self._cq: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._doorbell = threading.Condition(self._lock)   # SQ became nonempty
        self._not_full = threading.Condition(self._lock)   # SQ drained
        self._cq_ready = threading.Condition(self._lock)   # CQ grew

    def submit(self, req: SearchRequest, block: bool = False,
               timeout: Optional[float] = None) -> bool:
        """Append to the SQ and ring the doorbell.  Returns False when the
        queue is full (back-pressure) and ``block`` is False or timed out."""
        with self._lock:
            if len(self._sq) >= self.sq_depth:
                if not block:
                    return False
                ok = self._not_full.wait_for(
                    lambda: len(self._sq) < self.sq_depth, timeout)
                if not ok:
                    return False
            self._sq.append(req)
            self._doorbell.notify_all()
            return True

    def sq_len(self) -> int:
        with self._lock:
            return len(self._sq)

    def cq_len(self) -> int:
        with self._lock:
            return len(self._cq)

    def pop_submissions(self, max_n: int = 0) -> list[SearchRequest]:
        """Poller side: drain up to max_n (0 = all) submissions FIFO."""
        with self._lock:
            n = len(self._sq) if max_n <= 0 else min(max_n, len(self._sq))
            out = [self._sq.popleft() for _ in range(n)]
            if out:
                self._not_full.notify_all()
            return out

    def wait_submissions(self, timeout: Optional[float] = None) -> bool:
        """Poller side: sleep until the doorbell rings (or timeout)."""
        with self._lock:
            return self._doorbell.wait_for(lambda: len(self._sq) > 0, timeout)

    def complete(self, comps: list[Completion]) -> None:
        with self._lock:
            self._cq.extend(comps)
            if comps:
                self._cq_ready.notify_all()

    def poll(self, max_n: int = 0) -> list[Completion]:
        """Consumer side: drain up to max_n (0 = all) completions FIFO."""
        with self._lock:
            n = len(self._cq) if max_n <= 0 else min(max_n, len(self._cq))
            return [self._cq.popleft() for _ in range(n)]

    def wait_completions(self, n: int = 1,
                         timeout: Optional[float] = None) -> bool:
        with self._lock:
            return self._cq_ready.wait_for(lambda: len(self._cq) >= n, timeout)


def make_route_plan(cids_row: np.ndarray, nprobe: int, source) -> RoutePlan:
    """THE RoutePlan constructor — one definition of the probe signature
    (live cluster ids among the first ``nprobe`` routed), shared by the
    engine and by benches that pre-route a query pool, so the formation
    input measured offline is byte-for-byte what the engine feeds form."""
    n = int(nprobe)
    return RoutePlan(
        cids=cids_row, nprobe=n,
        probe_set=frozenset(int(c) for c in cids_row[:n] if c >= 0),
        source=source)


def route_requests(reqs: list, pipe, chunk: int = 0) -> None:
    """Tag each request with its RoutePlan from ``pipe`` in batched
    centroid+LLSP calls.  Requests already routed by this pipe are skipped
    (routing runs at most once per request per index version); a stale
    route from a swapped-out pipeline is recomputed against the live one.

    ``chunk`` (0 = everything at once) slices the call into warmed jit
    shapes: callers pass the batcher's max_batch so a deep pending pool
    never triggers a one-off compile of a pool-sized plan program mid-
    traffic — the cliff the pipeline warmup exists to prevent."""
    todo = [r for r in reqs
            if r.route is None or r.route.source is not pipe]
    if not todo:
        return
    step = len(todo) if chunk <= 0 else chunk
    for lo in range(0, len(todo), step):
        part = todo[lo:lo + step]
        qs = np.stack([r.query for r in part])
        tk = np.asarray([r.topk for r in part], np.int32)
        cids, nprobe = pipe.route(qs, tk)
        for i, r in enumerate(part):
            r.route = make_route_plan(cids[i], nprobe[i], pipe)


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    rejected: int = 0               # SQ-full back-pressure
    completed: int = 0
    shed: int = 0
    degraded: int = 0
    partial: int = 0                # answered from an incomplete shard set
    failed: int = 0                 # serving-path error; completed w/o payload
    batches: int = 0
    service_s: float = 0.0          # summed batch service time


class ServeEngine:
    """SQ -> batcher -> prefetch pipeline -> CQ, with an N-deep window.

    ``pipelines`` maps index name -> PrefetchPipeline (the §4.2 multi-index
    node).  The engine itself is pipeline-agnostic: it only needs the
    ``plan / prefetch / dispatch / harvest`` stage protocol (and, optionally,
    ``route`` for admission-time locality tagging).

    ``depth`` is the in-flight window: how many dispatched-but-unharvested
    batches the poller keeps on the device stream before blocking on the
    oldest readback.  depth=1 is the PR 2 double buffer (gather i+1 hides
    under scan i); deeper windows matter in the scan ≪ gather regime (TPU:
    the scan is device-fast, the host gather is the long pole), where one
    in-flight scan finishes long before the next union is gathered and the
    device sits idle unless more batches are queued behind it.
    """

    def __init__(self, pipelines: dict, batcher, qp: Optional[QueuePair] = None,
                 clock=time.monotonic, update_lanes: Optional[dict] = None,
                 depth: int = 1, obs: Optional[Observability] = None,
                 quality=None):
        self.pipelines = dict(pipelines)
        self.batcher = batcher
        self.qp = qp or QueuePair()
        self.clock = clock
        self.depth = max(int(depth), 1)
        self.stats = EngineStats()
        self.obs = obs if obs is not None else Observability.off()
        m = self.obs.metrics
        self._m_comp = m.counter("engine.completions")    # labeled by status
        self._m_reason = m.counter("engine.not_ok")       # labeled by reason
        self._h_lat = m.histogram("engine.latency_s")
        self._h_service = m.histogram("engine.batch_service_s")
        self._g_pending = m.gauge("engine.pending")
        # flash-tier re-rank stage (quantized serving): round/candidate
        # distributions + the adaptive-stop hit counter, fed straight from
        # the pipeline's StageTimes stamps at harvest
        self._h_rr_rounds = m.histogram("engine.rerank_rounds")
        self._h_rr_cands = m.histogram("engine.rerank_cands")
        self._h_rr_io = m.histogram("engine.rerank_io_s")
        self._m_rr_stop = m.counter("engine.rerank_stop")  # labeled by kind
        self._h_rr_round_size = m.histogram("engine.rerank_round_size")
        # quality observability (repro.obs.quality.QualityMonitor): fed one
        # call per harvested batch from the completion funnel — recall-proxy
        # streams, shadow audits, and the per-query telemetry harvest
        self.quality = quality
        self._req_ids = iter(range(1 << 62))
        self._swap_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._drain_on_stop = True
        # index lifecycle hooks (repro.lifecycle): the update lane(s) the
        # poller pumps between search batches, and the version manager that
        # routes batches to epochs (set by VersionManager.bind)
        self.update_lanes: dict = dict(update_lanes or {})
        self.versions = None
        for name in self.pipelines:
            self._register_router(name)

    # -- client side -------------------------------------------------------
    def submit(self, query: np.ndarray, topk: int, index: Optional[str] = None,
               deadline_s: Optional[float] = None, block: bool = False) -> int:
        """Submit one query; returns req_id, or -1 on SQ back-pressure."""
        now = self.clock()
        if index is None:
            index = next(iter(self.pipelines))
        elif index not in self.pipelines:
            # fail on the CLIENT thread: an unknown index reaching the
            # poller would kill the serve loop for everyone
            raise KeyError(f"unknown index {index!r}")
        req = SearchRequest(
            req_id=next(self._req_ids), index=index,
            query=np.asarray(query, np.float32), topk=int(topk),
            deadline=None if deadline_s is None else now + deadline_s,
            arrival=now, trace_id=self.obs.mint(),
        )
        if not self.qp.submit(req, block=block):
            self.stats.rejected += 1
            self._m_comp.inc(1, "rejected")
            return -1
        self.stats.submitted += 1
        if req.trace_id:
            # async request-lifetime span: closed by the terminal event in
            # _complete (overlapping lifetimes, so "b"/"e" not "X")
            self.obs.trace.abegin(
                "request", f"req-{req.trace_id}", t=now,
                trace_id=req.trace_id, track="requests",
                args={"index": index, "req_id": req.req_id})
        return req.req_id

    # -- index lifecycle (rebuild/swap flow of launch/serve.py) ------------
    def swap_pipeline(self, name: str, pipeline) -> None:
        """Atomically swap in a freshly built index (daily-rebuild flow)."""
        with self._swap_lock:
            self.pipelines[name] = pipeline
            self.batcher.add_index(name)
        self._register_router(name)

    def add_update_lane(self, name: str, lane) -> None:
        """Attach an update lane (lifecycle/ingest.py) for ``name``: the
        poller drains it between search batches, update_quantum at a time."""
        self.update_lanes = {**self.update_lanes, name: lane}

    def _pipeline(self, name: str):
        with self._swap_lock:
            return self.pipelines[name]

    def _pump_updates(self, now: float, drain: bool = False) -> int:
        """Apply a bounded quantum of pending update ops per lane (the
        interleave point: called between search batches, never inside one).
        ``drain=True`` flushes everything (shutdown path)."""
        budget = 0 if drain else self.batcher.policy.update_quantum
        n = 0
        for lane in self.update_lanes.values():
            n += lane.pump(now, budget)
        return n

    # -- poller ------------------------------------------------------------
    def _routing_pipeline(self, name: str):
        """Pipeline whose centroids route admissions for ``name`` — the
        current epoch's when versions are bound (no in-flight ref taken:
        routing is advisory, the batch takes its epoch at formation)."""
        if self.versions is not None:
            try:
                return self.versions.current(name).pipeline
            except KeyError:
                pass
        return self._pipeline(name)

    def _register_router(self, name: str) -> None:
        """Expose the index's probe router to the batcher.  Routing runs at
        most once per request, but WHERE it runs is amortization-driven:
        a burst drained off the SQ is routed immediately (one batched
        centroid+LLSP call), while trickle arrivals are left for the
        batcher to route in one pooled call at formation time — per-query
        routing cost identical to the PR 2 per-batch plan, never a
        per-arrival jit dispatch.

        ``route`` is optional in the stage protocol, so a swap to a
        route-less pipeline DEREGISTERS the router, and the closure
        re-checks the live pipeline every call — the poller must degrade
        to FIFO-style replanning, never crash, when an epoch swap changes
        the pipeline's capabilities mid-traffic."""
        routers = {**getattr(self.batcher, "routers", {})}
        pipe = self._routing_pipeline(name)
        if getattr(pipe, "route", None) is None:
            routers.pop(name, None)
            self.batcher.routers = routers
            return

        def router(reqs: list) -> None:
            live = self._routing_pipeline(name)
            if getattr(live, "route", None) is None:
                return
            route_requests(reqs, live, chunk=self.batcher.policy.max_batch)

        routers[name] = router
        self.batcher.routers = routers

    def _complete(self, comps: list) -> None:
        """THE completion funnel: every CQ push goes through here so the
        metrics (status/reason counters, latency histogram) and the trace's
        terminal events cannot drift from what clients observe."""
        if not comps:
            return
        tr = self.obs.trace
        for c in comps:
            self._m_comp.inc(1, c.status)
            if c.status != "ok":
                self._m_reason.inc(1, c.reason or c.status)
            if c.status != "shed":
                self._h_lat.observe(c.latency)
            if c.trace_id:
                # exactly ONE terminal instant per admitted trace — the
                # trace-integrity tests count these
                tr.instant(
                    f"done:{c.status}", t=c.completed, trace_id=c.trace_id,
                    track="requests",
                    args={"status": c.status, "reason": c.reason,
                          "latency_ms": round(c.latency * 1e3, 3)})
                tr.aend("request", f"req-{c.trace_id}", t=c.completed,
                        track="requests")
        self.qp.complete(comps)

    def _drain_sq(self, now: float) -> None:
        sheds, by_index = [], {}
        tracing = self.obs.tracing
        for req in self.qp.pop_submissions():
            c = self.batcher.add(req, now)
            if c is not None:
                sheds.append(c)
            else:
                if tracing and req.trace_id:
                    self.obs.trace.instant(
                        "admitted", t=now, trace_id=req.trace_id,
                        track="requests")
                by_index.setdefault(req.index, []).append(req)
        for name, group in by_index.items():
            # eager admission routing only when formation will use it AND
            # the drained group already amortizes the call (a burst);
            # trickles are routed in one pooled call at formation
            # (batcher.routers), fifo mode plans per batch as before
            if (self.batcher.policy.grouping == "locality"
                    and len(group) >= self.batcher.policy.pad):
                pipe = self._routing_pipeline(name)
                if getattr(pipe, "route", None) is not None:
                    route_requests(group, pipe,
                                   chunk=self.batcher.policy.max_batch)
        if sheds:
            self.stats.shed += len(sheds)
            self.stats.completed += len(sheds)
            self._complete(sheds)
        self._g_pending.set(self.batcher.pending())

    def _complete_batch(self, mb, result, done: float, epoch=None) -> None:
        comps = []
        partial = getattr(result, "partial", None)
        partial_reason = getattr(result, "partial_reason", "no_replica")
        quality = getattr(result, "quality", None)
        for i, req in enumerate(mb.requests):
            status, reason = ("degraded", "deadline") if mb.degraded[i] \
                else ("ok", "")
            if partial is not None and partial[i]:
                # fabric degraded mode outranks nprobe degradation: the
                # client must know the shard set was incomplete
                status, reason = "partial", partial_reason
                self.stats.partial += 1
            comps.append(Completion(
                req_id=req.req_id, index=req.index, status=status,
                ids=result.ids[i], dists=result.dists[i],
                nprobe=int(result.nprobe[i]),
                submitted=req.arrival, completed=done,
                reason=reason, trace_id=req.trace_id,
                quality=float(quality[i]) if quality is not None else -1.0,
            ))
        self.stats.degraded += int(mb.degraded.sum())
        self.stats.completed += len(comps)
        self.stats.batches += 1
        if epoch is not None:
            self.versions.harvested(epoch)
        if result.fresh_seq >= 0:
            lane = self.update_lanes.get(mb.index)
            if lane is not None:
                # visibility stamp: every update op covered by this batch's
                # snapshot now has a search response that could contain it
                lane.mark_visible(result.fresh_seq, done)
        # marginal batch cost = its own stage durations, NOT wall span from
        # plan_start (in the pipelined steady state that span also covers
        # the previous batch's in-flight scan and would inflate the EWMA
        # ~2x, making admission control shed meetable requests)
        t = result.times
        service = (t.plan_end - t.plan_start) + (t.scan_done - t.scan_dispatch)
        if t.rerank_end > t.rerank_start:
            service += t.rerank_end - t.rerank_start
            self._h_rr_rounds.observe(t.rerank_rounds)
            self._h_rr_cands.observe(t.rerank_cands)
            self._h_rr_io.observe(t.rerank_io_s)
            self._m_rr_stop.inc(
                1, "stable" if t.rerank_stable_stop else "exhausted")
            if t.rerank_round_size:
                self._h_rr_round_size.observe(t.rerank_round_size)
        self.stats.service_s += service
        self._h_service.observe(service)
        self.batcher.observe(len(mb.requests), service)
        if self.obs.tracing:
            self._emit_batch_spans(t, mb)
        if self.quality is not None:
            self.quality.observe_batch(
                mb.requests, comps,
                shards=getattr(result, "shards", None),
                rerank_rounds=t.rerank_rounds)
        self._complete(comps)

    def _emit_batch_spans(self, t, mb) -> None:
        """Stage spans for one served batch, from the StageTimes stamps the
        pipeline already took (zero extra clock reads).  Batches overlap in
        the depth>1 window, so each goes on a rotating ``batch-N`` lane —
        spans within one batch are sequential and nest under the parent."""
        tids = [r.trace_id for r in mb.requests if r.trace_id]
        if not tids:
            return
        spans = stage_spans(t)
        if not spans:
            return
        lane = f"batch-{self.stats.batches % 16}"
        tr = self.obs.trace
        tr.span("batch", min(a for _, a, _ in spans),
                max(b for _, _, b in spans), trace_id=tids[0], track=lane,
                args={"n": len(mb.requests), "index": mb.index,
                      "trace_ids": tids[:32]})
        for name, a, b in spans:
            tr.span(name, a, b, track=lane)

    def _form_and_plan(self, now: float, force: bool = False):
        """Form the next micro-batch and run its plan stage (device idle
        here by construction — before the current batch's scan dispatch).

        Epoch routing happens HERE: the batch takes an in-flight reference
        on the current epoch and carries it to harvest, so a concurrent
        swap cannot re-route (or early-retire) a batch mid-flight."""
        mb, sheds = self.batcher.form(now, force=force)
        if sheds:
            self.stats.shed += len(sheds)
            self.stats.completed += len(sheds)
            self._complete(sheds)
        if mb is None:
            return None
        epoch = None
        if self.versions is not None:
            epoch = self.versions.route(mb.index)
        pipe = epoch.pipeline if epoch is not None else self._pipeline(mb.index)
        queries = np.stack([r.query for r in mb.requests])
        topk = np.asarray([r.topk for r in mb.requests], np.int32)
        # reuse the admission-time routing when every request in the batch
        # was routed by THIS pipeline; a stale route (epoch swapped between
        # admission and formation) replans against the live centroids
        routed = None
        routes = [r.route for r in mb.requests]
        if all(rt is not None and rt.source is pipe for rt in routes):
            routed = (np.stack([rt.cids for rt in routes]),
                      np.asarray([rt.nprobe for rt in routes], np.int32))
        kwargs = {}
        if getattr(pipe, "accepts_deadline", False):
            # deadline-aware pipelines (the sharded fabric) hedge and give
            # up against the batch's tightest request deadline
            dls = [r.deadline for r in mb.requests if r.deadline is not None]
            kwargs["deadline"] = min(dls) if dls else None
        try:
            plan = pipe.plan(queries, topk, nprobe_cap=mb.nprobe_cap,
                             routed=routed, **kwargs)
        except Exception:
            # the batch is already formed — its requests MUST complete
            # (failed), never be abandoned with clients blocked on the CQ
            self._fail_batch(mb, now, epoch=epoch, reason="plan_error")
            return None
        if self.obs.tracing:
            # sampled request identities ride the plan into the fabric so
            # every shard task (incl. requeue/hedge) tags its queries
            plan.trace_ids = tuple(
                r.trace_id for r in mb.requests if r.trace_id)
        return mb, pipe, plan, epoch

    def step(self, now: Optional[float] = None, force: bool = True) -> int:
        """Synchronous single-batch step (tests / virtual clock): drain the
        SQ, form one micro-batch, serve it end-to-end.  Returns the number
        of completions produced."""
        now = self.clock() if now is None else now
        before = self.stats.completed
        self._drain_sq(now)
        self._pump_updates(now)
        planned = self._form_and_plan(now, force=force)
        if planned is not None:
            mb, pipe, plan, epoch = planned
            result = pipe.harvest(pipe.dispatch(pipe.prefetch(plan)))
            self._complete_batch(mb, result,
                                 self.clock() if now is None else now,
                                 epoch=epoch)
        return self.stats.completed - before

    def _fail_batch(self, mb, done: float, epoch=None,
                    reason: str = "serve_error") -> None:
        """Complete a formed batch as "failed" — the serving path errored,
        but every client gets a CQ entry (no abandoned requests, the
        shutdown/crash-drain invariant).  ``reason`` names the stage that
        errored ("plan_error", "prefetch_error", …)."""
        comps = [Completion(
            req_id=r.req_id, index=r.index, status="failed",
            ids=None, dists=None, nprobe=0,
            submitted=r.arrival, completed=done,
            reason=reason, trace_id=r.trace_id,
        ) for r in mb.requests]
        self.stats.failed += len(comps)
        self.stats.completed += len(comps)
        self.stats.batches += 1
        if epoch is not None:
            self.versions.harvested(epoch)
        self._complete(comps)

    def _flush_pending(self) -> None:
        """Shed everything admitted but not yet formed (batcher pools) plus
        SQ residents — the ``stop(drain=False)`` path used to abandon both,
        leaving blocked clients waiting on completions that never came."""
        now = self.clock()
        reqs = self.batcher.drain_pending() + self.qp.pop_submissions()
        if not reqs:
            return
        comps = [Completion(
            req_id=r.req_id, index=r.index, status="shed",
            ids=None, dists=None, nprobe=0,
            submitted=r.arrival, completed=now,
            reason="drain", trace_id=r.trace_id,
        ) for r in reqs]
        self.stats.shed += len(comps)
        self.stats.completed += len(comps)
        self._complete(comps)

    def _harvest_head(self, inflight) -> None:
        mb, pipe, infl, epoch = inflight.popleft()
        try:
            result = pipe.harvest(infl)
        except Exception:
            # a harvest error must not kill the poller with the window
            # still holding batches: this batch fails, the rest continue
            self._fail_batch(mb, self.clock(), epoch=epoch,
                             reason="harvest_error")
            return
        self._complete_batch(mb, result, self.clock(), epoch=epoch)

    def _prep_or_fail(self, planned):
        """Run the prefetch stage; on error the batch completes as failed
        instead of being dropped between stages."""
        mb, pipe, plan, epoch = planned
        try:
            return (mb, pipe, pipe.prefetch(plan), epoch)
        except Exception:
            self._fail_batch(mb, self.clock(), epoch=epoch,
                             reason="prefetch_error")
            return None

    def _dispatch_or_fail(self, prep, inflight) -> None:
        mb, pipe, h, epoch = prep
        try:
            inflight.append((mb, pipe, pipe.dispatch(h), epoch))
        except Exception:
            self._fail_batch(mb, self.clock(), epoch=epoch,
                             reason="dispatch_error")

    def _serve_loop(self) -> None:
        """Overlapped poller: while up to ``depth`` batches scan on device,
        the next batch is formed, planned, and its cluster union gathered /
        streamed on host.

        The plan stage of the next batch runs BEFORE the prepared batch's
        scan dispatch so its (small) device work is not queued behind the
        (large) scan on the backend's in-order execution stream — this
        ordering is what makes the host gather actually land inside the
        scan-in-flight window.  The in-flight deque holds dispatched,
        unharvested batches; the poller only blocks on the OLDEST readback,
        and only when the window is full or there is nothing left to prep —
        so with depth >= 2 a short scan finishing early never idles the
        device while the next gather is still on the host.
        """
        prep = None                    # (mb, pipe, prefetch-handle, epoch)
        inflight = collections.deque() # (mb, pipe, scan-handle, epoch)
        try:
            while not self._stop.is_set():
                now = self.clock()
                self._drain_sq(now)
                # update interleave point: BETWEEN batches, a bounded
                # quantum — an update storm back-pressures its own SQ,
                # search cadence holds
                self._pump_updates(now)
                if prep is None:
                    planned = self._form_and_plan(now)
                    if planned is not None:
                        prep = self._prep_or_fail(planned)
                        continue       # give the SQ one more drain pass
                    if inflight:
                        self._harvest_head(inflight)
                        continue
                    self.qp.wait_submissions(
                        timeout=self.batcher.policy.max_wait_s)
                    continue
                if len(inflight) >= self.depth:
                    self._harvest_head(inflight)
                    continue
                # commit the prepared batch: plan the NEXT batch first
                # (device idle for it), dispatch the scan into the in-flight
                # window, then gather the next batch under the window's
                # scans.
                nxt = self._form_and_plan(now)
                self._dispatch_or_fail(prep, inflight)
                prep = None
                if nxt is not None:
                    prep = self._prep_or_fail(nxt)
            # drain: finish anything still prepared or in flight
            if prep is not None:
                self._dispatch_or_fail(prep, inflight)
                prep = None
            while inflight:
                self._harvest_head(inflight)
            while self._drain_on_stop:
                now = self.clock()
                self._drain_sq(now)
                self._pump_updates(now, drain=True)
                planned = self._form_and_plan(now, force=True)
                if planned is None:
                    if self.batcher.pending() > 0:
                        continue      # a fully-shed batch is not "drained"
                    break
                mb, pipe, plan, epoch = planned
                try:
                    result = pipe.harvest(
                        pipe.dispatch(pipe.prefetch(plan)))
                except Exception:
                    self._fail_batch(mb, self.clock(), epoch=epoch,
                                     reason="harvest_error")
                    continue
                self._complete_batch(mb, result, self.clock(), epoch=epoch)
            if not self._drain_on_stop:
                self._flush_pending()
        except BaseException:
            # last-resort crash drain: whatever still holds requests when
            # the poller unwinds (targeted guards missed, or a bug in the
            # loop itself) completes as failed/shed rather than leaving
            # clients blocked on CQ entries that will never arrive
            if prep is not None:
                mb, _, _, epoch = prep
                self._fail_batch(mb, self.clock(), epoch=epoch,
                                 reason="crash_drain")
            while inflight:
                mb, _, _, epoch = inflight.popleft()
                self._fail_batch(mb, self.clock(), epoch=epoch,
                                 reason="crash_drain")
            self._flush_pending()
            raise

    def start(self) -> None:
        assert self._thread is None, "engine already started"
        self._stop.clear()
        self._drain_on_stop = True
        self._thread = threading.Thread(
            target=self._serve_loop, name="serve-poller", daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the poller; by default finishes every admitted request."""
        if self._thread is None:
            return
        self._drain_on_stop = drain
        self._stop.set()
        self._thread.join()
        self._thread = None
