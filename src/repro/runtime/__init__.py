"""Async serving runtime — the §4.1 userspace I/O stack, adapted to TPU.

Paper-to-module map:

=====================  ====================================================
paper §4.1 component   runtime module
=====================  ====================================================
SQ/CQ queue pairs,     :mod:`repro.runtime.engine` — bounded submission /
doorbells, polling     completion queues, doorbell conditions, poller thread
SSD-read / scan        :mod:`repro.runtime.pipeline` — N-deep prefetch
overlap                window over plan/prefetch/dispatch/harvest stages;
                       the next batches' gathers overlap the in-flight
                       scans (depth=1 is the PR 2 double buffer)
request coalescing,    :mod:`repro.runtime.batcher` — dynamic micro-batching
overload control,      with probe-overlap (locality) grouped formation on
locality grouping      admission-time routes, deadline-aware shed/degrade
                       admission control iterated to a fixed point on the
                       kept set, and round-robin fairness across
                       co-resident indexes
production traffic     :mod:`repro.runtime.loadgen` — seeded Poisson /
                       bursty / multi-tenant / locality-skewed /
                       hot-cluster arrival traces
=====================  ====================================================
"""
from .batcher import BatchPolicy, BatcherStats, DynamicBatcher, MicroBatch
from .engine import (
    Completion,
    EngineStats,
    QueuePair,
    RoutePlan,
    SearchRequest,
    ServeEngine,
)
from .loadgen import (
    Arrival,
    TenantSpec,
    UpdateArrival,
    bursty_trace,
    drifting_trace,
    hot_cluster_trace,
    locality_skewed_trace,
    merge_timelines,
    multi_tenant_trace,
    poisson_trace,
    shard_skewed_trace,
    update_trace,
)
from .pipeline import (
    BatchResult,
    PrefetchPipeline,
    RerankConfig,
    StageTimes,
    inflight_depth,
    latency_percentiles,
    make_quantized_pipeline,
    max_id_replicas,
    overlap_efficiency,
    rerank_overlap_efficiency,
)
