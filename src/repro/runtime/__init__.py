"""Async serving runtime — the §4.1 userspace I/O stack, adapted to TPU.

Paper-to-module map:

=====================  ====================================================
paper §4.1 component   runtime module
=====================  ====================================================
SQ/CQ queue pairs,     :mod:`repro.runtime.engine` — bounded submission /
doorbells, polling     completion queues, doorbell conditions, poller thread
SSD-read / scan        :mod:`repro.runtime.pipeline` — double-buffered
overlap                plan/prefetch/dispatch/harvest stages; gather of
                       batch i+1 overlaps the in-flight scan of batch i
request coalescing,    :mod:`repro.runtime.batcher` — dynamic micro-batching
overload control       with deadline-aware shed/degrade admission control
                       and round-robin fairness across co-resident indexes
production traffic     :mod:`repro.runtime.loadgen` — seeded Poisson /
                       bursty / multi-tenant arrival traces
=====================  ====================================================
"""
from .batcher import BatchPolicy, BatcherStats, DynamicBatcher, MicroBatch
from .engine import (
    Completion,
    EngineStats,
    QueuePair,
    SearchRequest,
    ServeEngine,
)
from .loadgen import (
    Arrival,
    TenantSpec,
    UpdateArrival,
    bursty_trace,
    merge_timelines,
    multi_tenant_trace,
    poisson_trace,
    update_trace,
)
from .pipeline import (
    BatchResult,
    PrefetchPipeline,
    StageTimes,
    latency_percentiles,
    max_id_replicas,
    overlap_efficiency,
)
