"""Tiled pairwise squared-L2 Pallas kernel.

The k-means assignment step and the centroid scan are both ``queries x points``
distance matrices — the construction-stage hot spot the paper offloads to
GPUs (§4.4).  TPU-native realization: block the (N, M) output into MXU-aligned
tiles, accumulate -2*A@B^T over D-blocks in VMEM, and add the squared norms on
the final D step.  Grid = (N/BN, M/BM, D/BD); the D axis is the innermost
(sequential) dimension so each output tile stays resident in VMEM while its
accumulation completes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref, *, n_d_blocks: int):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)          # (BN, BD)
    b = b_ref[...].astype(jnp.float32)          # (BM, BD)
    partial = (
        jnp.sum(a * a, axis=1, keepdims=True)
        - 2.0 * jax.lax.dot_general(
            a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        + jnp.sum(b * b, axis=1, keepdims=True).T
    )
    o_ref[...] += partial

    @pl.when(kd == n_d_blocks - 1)
    def _final():
        o_ref[...] = jnp.maximum(o_ref[...], 0.0)


@functools.partial(
    jax.jit, static_argnames=("bn", "bm", "bd", "interpret")
)
def pairwise_l2(
    a: jax.Array,
    b: jax.Array,
    *,
    bn: int = 128,
    bm: int = 128,
    bd: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """a: (N, D), b: (M, D) -> (N, M) squared L2 in f32.

    Pads every dim to its block multiple (edge tiles are handled by padding:
    padded rows/cols produce garbage distances that are sliced away; padded D
    contributes zeros to every term, which is exact).
    """
    n, d = a.shape
    m, _ = b.shape
    bn_ = min(bn, _ceil_mult(n, 8))
    bm_ = min(bm, _ceil_mult(m, 128))
    bd_ = min(bd, _ceil_mult(d, 128))
    npad, mpad, dpad = (-n) % bn_, (-m) % bm_, (-d) % bd_
    ap = jnp.pad(a, ((0, npad), (0, dpad)))
    bp = jnp.pad(b, ((0, mpad), (0, dpad)))
    gn, gm, gd = ap.shape[0] // bn_, bp.shape[0] // bm_, ap.shape[1] // bd_

    out = pl.pallas_call(
        functools.partial(_kernel, n_d_blocks=gd),
        grid=(gn, gm, gd),
        in_specs=[
            pl.BlockSpec((bn_, bd_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm_, bd_), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn_, bm_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[0]), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:n, :m]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
