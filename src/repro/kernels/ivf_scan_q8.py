"""Fused int8-residual posting scan (Pallas) — the optimized serving hot
path from EXPERIMENTS §Perf it.3.

Same structure as ivf_scan (scalar-prefetch block table, one posting block
DMA'd HBM->VMEM per (query, probe) grid step) but the payload is the int8
RESIDUAL code from core/quantize.py at 1/4 the HBM bytes; the kernel
dequantizes in registers and applies the closed-form residual expansion:

    ||q - (c + s r8)||^2 = ||q - c||^2 - 2 s (q - c).r8 + s^2 ||r8||^2

Operands per grid step: q8 block (L, D) int8, centroid row (D,), per-cluster
scale, precomputed s^2||r8||^2 row (L,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cids_ref, mask_ref, q_ref, cent_ref, scale_ref, norm2_ref,
            q8_ref, o_ref):
    b = pl.program_id(0)
    p = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)             # (1, D)
    cent = cent_ref[...].astype(jnp.float32)       # (1, D)
    r8 = q8_ref[0].astype(jnp.float32)             # (L, D)
    s = scale_ref[0, 0].astype(jnp.float32)        # ()
    n2 = norm2_ref[...].astype(jnp.float32)        # (1, L)
    qc = q - cent                                  # (1, D)
    cross = jax.lax.dot_general(
        qc, r8, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (1, L)
    d = jnp.sum(qc * qc) - 2.0 * s * cross + n2
    d = jnp.maximum(d, 0.0)
    live = mask_ref[b, p] > 0
    o_ref[...] = jnp.where(live, d[:, None, :], jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_scan_q8(
    q8: jax.Array,         # (C, L, D) int8 residual codes
    scale: jax.Array,      # (C, 1, 1) f32
    norm2: jax.Array,      # (C, L) f32
    centroids: jax.Array,  # (C, D) f32
    cids: jax.Array,       # (B, P) int32
    mask: jax.Array,       # (B, P) bool
    queries: jax.Array,    # (B, D)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, P, L) f32 distances; masked probes +inf."""
    C, L, D = q8.shape
    B, P = cids.shape
    safe = jnp.clip(cids, 0, C - 1).astype(jnp.int32)
    mask_i = mask.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, p, c_p, m_p: (b, 0)),
            pl.BlockSpec((1, D), lambda b, p, c_p, m_p: (c_p[b, p], 0)),
            pl.BlockSpec((1, 1, 1), lambda b, p, c_p, m_p: (c_p[b, p], 0, 0)),
            pl.BlockSpec((1, L), lambda b, p, c_p, m_p: (c_p[b, p], 0)),
            pl.BlockSpec((1, L, D), lambda b, p, c_p, m_p: (c_p[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L), lambda b, p, c_p, m_p: (b, p, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P, L), jnp.float32),
        interpret=interpret,
    )(safe, mask_i, queries, centroids, scale, norm2, q8)
