"""Fused int8-residual posting scan (Pallas) — the optimized serving hot
path from EXPERIMENTS §Perf it.3.

Same structure as ivf_scan (scalar-prefetch block table, one posting block
DMA'd HBM->VMEM per grid step) but the payload is the int8 RESIDUAL code from
core/quantize.py at 1/4 the HBM bytes; the kernel dequantizes in registers
and applies the closed-form residual expansion:

    ||q - (c + s r8)||^2 = ||q - c||^2 - 2 s (q - c).r8 + s^2 ||r8||^2

Operands per grid step: q8 block (L, D) int8, centroid row (D,), per-cluster
scale, precomputed s^2||r8||^2 row (L,).

Two variants:

* ``ivf_scan_q8``      — legacy (B, P, L) full-distance writeback.
* ``ivf_scan_q8_topk`` — candidate-compressed: query-tiled grid + in-VMEM
  running top-k2 with in-kernel posting-id resolution, emitting (B, k2)
  candidates.  See kernels/ivf_scan.py for the grid/scratch design; this
  kernel shares its probe plan and top-k merge helpers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ivf_scan import _extract_topk, plan_tile_probes


def _kernel(cids_ref, mask_ref, q_ref, cent_ref, scale_ref, norm2_ref,
            q8_ref, o_ref):
    b = pl.program_id(0)
    p = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)             # (1, D)
    cent = cent_ref[...].astype(jnp.float32)       # (1, D)
    r8 = q8_ref[0].astype(jnp.float32)             # (L, D)
    s = scale_ref[0, 0].astype(jnp.float32)        # ()
    n2 = norm2_ref[...].astype(jnp.float32)        # (1, L)
    qc = q - cent                                  # (1, D)
    cross = jax.lax.dot_general(
        qc, r8, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (1, L)
    d = jnp.sum(qc * qc) - 2.0 * s * cross + n2
    d = jnp.maximum(d, 0.0)
    live = mask_ref[b, p] > 0
    o_ref[...] = jnp.where(live, d[:, None, :], jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_scan_q8(
    q8: jax.Array,         # (C, L, D) int8 residual codes
    scale: jax.Array,      # (C, 1, 1) f32
    norm2: jax.Array,      # (C, L) f32
    centroids: jax.Array,  # (C, D) f32
    cids: jax.Array,       # (B, P) int32
    mask: jax.Array,       # (B, P) bool
    queries: jax.Array,    # (B, D)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, P, L) f32 distances; masked probes +inf."""
    C, L, D = q8.shape
    B, P = cids.shape
    safe = jnp.clip(cids, 0, C - 1).astype(jnp.int32)
    mask_i = mask.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, p, c_p, m_p: (b, 0)),
            pl.BlockSpec((1, D), lambda b, p, c_p, m_p: (c_p[b, p], 0)),
            pl.BlockSpec((1, 1, 1), lambda b, p, c_p, m_p: (c_p[b, p], 0, 0)),
            pl.BlockSpec((1, L), lambda b, p, c_p, m_p: (c_p[b, p], 0)),
            pl.BlockSpec((1, L, D), lambda b, p, c_p, m_p: (c_p[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L), lambda b, p, c_p, m_p: (b, p, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P, L), jnp.float32),
        interpret=interpret,
    )(safe, mask_i, queries, centroids, scale, norm2, q8)


# --------------------------------------------------------------------------
# fused in-kernel top-k over int8 residual postings
# --------------------------------------------------------------------------
def _qtile_topk_q8_kernel(tc_ref, q_ref, cent_ref, scale_ref, norm2_ref,
                          pids_ref, qsel_ref, q8_ref, od_ref, oi_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, jnp.inf, od_ref.dtype)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, oi_ref.dtype)

    q = q_ref[...].astype(jnp.float32)                  # (bq, D)
    cent = cent_ref[...].astype(jnp.float32)            # (1, D)
    r8 = q8_ref[0].astype(jnp.float32)                  # (L, D)
    sc = scale_ref[0, 0, 0].astype(jnp.float32)         # ()
    n2 = norm2_ref[...].astype(jnp.float32)             # (1, L)
    qc = q - cent                                       # (bq, D)
    cross = jax.lax.dot_general(
        qc, r8, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                   # (bq, L) — one MXU op
    d = jnp.sum(qc * qc, axis=1, keepdims=True) - 2.0 * sc * cross + n2
    d = jnp.maximum(d, 0.0)
    bq = d.shape[0]
    sel = jnp.reshape(qsel_ref[...], (bq, 1)) > 0       # (bq, 1)
    ids = jnp.broadcast_to(pids_ref[...], d.shape).astype(jnp.int32)
    d = jnp.where(sel & (ids >= 0), d, jnp.inf)
    cat_d = jnp.concatenate([od_ref[...], d], axis=1)
    cat_i = jnp.concatenate([oi_ref[...], ids], axis=1)
    nd, ni = _extract_topk(cat_d, cat_i, od_ref.shape[-1])
    od_ref[...] = nd
    oi_ref[...] = ni


@functools.partial(jax.jit, static_argnames=("k2", "bq", "interpret"))
def ivf_scan_q8_topk(
    q8: jax.Array,           # (C, L, D) int8 residual codes
    scale: jax.Array,        # (C, 1, 1) f32
    norm2: jax.Array,        # (C, L) f32
    centroids: jax.Array,    # (C, D) f32
    posting_ids: jax.Array,  # (C, L) int32, -1 = pad slot
    cids: jax.Array,         # (B, P) int32
    mask: jax.Array,         # (B, P) bool
    queries: jax.Array,      # (B, D)
    *,
    k2: int,
    bq: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused q8 scan + in-kernel top-k2: ((B, k2) dists, (B, k2) ids).

    Same candidate contract as ivf_scan_topk; the per-id min collapses the
    slightly-different residual distances of closure duplicates (each copy is
    quantized against its own centroid)."""
    C, L, D = q8.shape
    B, P = cids.shape
    padb = (-B) % bq
    if padb:
        queries = jnp.pad(queries, ((0, padb), (0, 0)))
        cids = jnp.pad(cids, ((0, padb), (0, 0)))
        mask = jnp.pad(jnp.asarray(mask, bool), ((0, padb), (0, 0)))
    bp = B + padb
    nb = bp // bq
    s_len = bq * P
    tile_cids, qsel = plan_tile_probes(cids, mask, bq, C)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, s_len),
        in_specs=[
            pl.BlockSpec((bq, D), lambda t, s, tc: (t, 0)),
            pl.BlockSpec((1, D), lambda t, s, tc: (tc[t, s], 0)),
            pl.BlockSpec((1, 1, 1), lambda t, s, tc: (tc[t, s], 0, 0)),
            pl.BlockSpec((1, L), lambda t, s, tc: (tc[t, s], 0)),
            pl.BlockSpec((1, L), lambda t, s, tc: (tc[t, s], 0)),
            pl.BlockSpec((1, 1, bq), lambda t, s, tc: (t, s, 0)),
            pl.BlockSpec((1, L, D), lambda t, s, tc: (tc[t, s], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k2), lambda t, s, tc: (t, 0)),
            pl.BlockSpec((bq, k2), lambda t, s, tc: (t, 0)),
        ],
    )
    od, oi = pl.pallas_call(
        _qtile_topk_q8_kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((bp, k2), jnp.float32),
            jax.ShapeDtypeStruct((bp, k2), jnp.int32),
        ),
        interpret=interpret,
    )(tile_cids, queries, centroids, scale, norm2,
      posting_ids.astype(jnp.int32), qsel, q8)
    return od[:B], oi[:B]
