"""Fused k-means assign-and-accumulate — the construction hot path as a
Pallas kernel (paper §4.4 / Fig. 13: GPU-offloaded clustering, re-expressed
for the TPU memory hierarchy).

The unfused Lloyd E-step materializes the full (N, K) distance matrix in HBM
every iteration, reads it back for the argmin, and then runs the M-step as a
host-side scatter-add — three round trips through the slowest tier for one
logical reduction.  This kernel fuses E and M: each grid step DMAs one
(BN, D) point block into VMEM, distances it against the WHOLE centroid block
with a single (BN, D) x (D, K) MXU matmul, takes the per-point argmin, and
immediately folds the block into per-centroid partial sums and counts that
stay RESIDENT in VMEM across the entire point-grid dimension (the same
output-block-revisiting trick as ``ivf_scan_topk``'s candidate accumulator:
the sums/counts BlockSpecs map every grid step to block (0, 0), so they are
flushed to HBM exactly once).  What crosses the pallas_call boundary is the
ANSWER of one Lloyd iteration —

    assignments (N,) i32 + min-dists (N,) f32 + sums (K, D) f32 + counts (K,)

— never the (N, K) intermediate.  Writeback drops from N*K*4 bytes to
(K*D + K + 2N)*4 bytes: ~300x at N=50k, K=1024, D=64.

The one-hot fold is itself an MXU op: onehot(assign)^T @ points is a
(K, BN) x (BN, D) matmul, so the M-step rides the systolic array instead of
a gather/scatter unit.  Padding contract: padded D columns are zeros (exact
for every distance term), padded K rows are masked to +inf before the argmin
(so they accumulate nothing), padded N rows are masked out of the one-hot
(so they perturb no sums) and sliced off the assignment outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, a_ref, m_ref, s_ref, cnt_ref, *,
            n_pts: int, n_cents: int, bn: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = x_ref[...].astype(jnp.float32)                  # (BN, Dp)
    c = c_ref[...].astype(jnp.float32)                  # (Kp, Dp)
    d = (
        jnp.sum(x * x, axis=1, keepdims=True)
        - 2.0 * jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        + jnp.sum(c * c, axis=1)[None, :]
    )                                                   # (BN, Kp) — one MXU op
    d = jnp.maximum(d, 0.0)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(col < n_cents, d, jnp.inf)            # padded centroids dead
    a = jnp.argmin(d, axis=1).astype(jnp.int32)         # (BN,)
    md = jnp.min(d, axis=1)
    a_ref[...] = a[:, None]
    m_ref[...] = md[:, None]
    row = jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)[:, 0] + i * bn
    live = row < n_pts                                  # padded points dead
    oh = ((col == a[:, None]) & live[:, None]).astype(jnp.float32)  # (BN, Kp)
    s_ref[...] += jax.lax.dot_general(                  # (Kp, Dp) — MXU M-step
        oh, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    cnt_ref[...] += jnp.sum(oh, axis=0)[None, :]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kmeans_assign_update(
    x: jax.Array,          # (N, D) points
    centroids: jax.Array,  # (K, D)
    *,
    bn: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused Lloyd iteration's data movement.

    Returns (assign (N,) i32, min_dist (N,) f32, sums (K, D) f32,
    counts (K,) f32) where sums[k] = Σ x[i] over assign[i] == k and
    counts[k] = |{i : assign[i] == k}|.  The (N, K) distance matrix never
    leaves VMEM.  Centroids (and the sums accumulator) are kept WHOLE in
    VMEM as (Kp, Dp) f32 blocks — the kernel does not chunk K, because the
    argmin must be global before any accumulation.  Callers whose working
    set (centroids + sums + the (BN, Kp) distance/one-hot tiles) exceeds
    the VMEM budget go through ops.kmeans_assign_update_tile, which
    estimates that footprint and falls back to the jnp oracle; the build
    pipeline itself stays far below it (hierarchical splitting keeps
    per-call K small).
    """
    n, d = x.shape
    k = centroids.shape[0]
    bn_ = min(bn, _ceil_mult(n, 8))
    kp = _ceil_mult(k, 128)
    dp = _ceil_mult(d, 128)
    xp = jnp.pad(x, ((0, (-n) % bn_), (0, dp - d)))
    cp = jnp.pad(centroids, ((0, kp - k), (0, dp - d)))
    n_blocks = xp.shape[0] // bn_

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_kernel, n_pts=n, n_cents=k, bn=bn_),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bn_, dp), lambda i: (i, 0)),
            pl.BlockSpec((kp, dp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            # revisited across the whole point grid: VMEM-resident accumulators
            pl.BlockSpec((kp, dp), lambda i: (0, 0)),
            pl.BlockSpec((1, kp), lambda i: (0, 0)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((1, kp), jnp.float32),
        ),
        interpret=interpret,
    )(xp, cp)
    return a[:n, 0], md[:n, 0], sums[:k, :d], counts[0, :k]
