"""Fused posting-list scan — the Helmsman serving hot path as a Pallas kernel.

Paper (§4.2): cluster reads are fixed-size, batched, dependency-free; SPDK
bypasses the kernel so one PCIe doorbell serves a whole batch.  TPU-native
adaptation: the posting tensor lives in HBM; the Pallas grid pipeline streams
one posting block per (query, probe) step into VMEM (double-buffered DMA — the
"doorbell batch"), computes squared-L2 distances against the query in the same
kernel, and writes only the (B, P, L) distance tile back.  The gathered
vectors never round-trip through HBM, which is precisely the paper's
"eliminate software overhead between the search engine and the device" point
re-expressed for the HBM->VMEM hierarchy.

The data-dependent block index (which cluster to DMA) uses Pallas scalar
prefetch: the cluster-id table (B, P) is a scalar-prefetch operand consumed by
the BlockSpec index_map — the same mechanism as paged-attention block tables.

Two variants:

* ``ivf_scan``            — query-major: grid (B, P), block (L, D) per step.
  Matches the ANNS access pattern exactly; memory-bound by design (the paper's
  workload is bandwidth-bound too).
* ``ivf_scan_clustermajor`` (see ops.py) — beyond-paper variant that inverts
  the loop to cluster-major so each posting block is distanced against a
  whole query tile with one MXU matmul (exploits probe overlap across queries,
  cf. §6.2 "transient query bursts target the same clusters").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qmajor_kernel(cids_ref, mask_ref, q_ref, post_ref, o_ref):
    b = pl.program_id(0)
    p = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)            # (1, D)
    blk = post_ref[0].astype(jnp.float32)         # (L, D)
    # ||q||^2 - 2 q.blk^T + ||blk||^2  -> (1, L)
    d = (
        jnp.sum(q * q)
        - 2.0 * jax.lax.dot_general(
            q, blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        + jnp.sum(blk * blk, axis=1)[None, :]
    )
    d = jnp.maximum(d, 0.0)
    live = mask_ref[b, p] > 0
    o_ref[...] = jnp.where(live, d[:, None, :], jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_scan(
    postings: jax.Array,   # (C, L, D)
    cids: jax.Array,       # (B, P) int32
    mask: jax.Array,       # (B, P) bool
    queries: jax.Array,    # (B, D)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, P, L) f32 distances; masked probes +inf."""
    C, L, D = postings.shape
    B, P = cids.shape
    safe_cids = jnp.clip(cids, 0, C - 1).astype(jnp.int32)
    mask_i = mask.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, p, cids_p, mask_p: (b, 0)),
            pl.BlockSpec((1, L, D), lambda b, p, cids_p, mask_p: (cids_p[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L), lambda b, p, cids_p, mask_p: (b, p, 0)),
    )
    return pl.pallas_call(
        _qmajor_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P, L), jnp.float32),
        interpret=interpret,
    )(safe_cids, mask_i, queries, postings)


def _cmajor_kernel(active_ref, qsel_ref, q_ref, post_ref, o_ref):
    a = pl.program_id(0)
    blk = post_ref[...].astype(jnp.float32)[0]    # (L, D)
    q = q_ref[...].astype(jnp.float32)            # (B, D)
    d = (
        jnp.sum(blk * blk, axis=1)[:, None]
        - 2.0 * jax.lax.dot_general(
            blk, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        + jnp.sum(q * q, axis=1)[None, :]
    )                                             # (L, B) — one MXU matmul
    d = jnp.maximum(d, 0.0)
    sel = qsel_ref[a, :][None, :] > 0             # (1, B)
    o_ref[...] = jnp.where(sel, d, jnp.inf)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_scan_clustermajor(
    postings: jax.Array,   # (C, L, D)
    active: jax.Array,     # (A,) int32
    qsel: jax.Array,       # (A, B) bool
    queries: jax.Array,    # (B, D)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns (A, L, B) f32 distances; unselected (cluster, query) pairs +inf."""
    C, L, D = postings.shape
    A = active.shape[0]
    B = queries.shape[0]
    safe = jnp.clip(active, 0, C - 1).astype(jnp.int32)
    qsel_i = qsel.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(A,),
        in_specs=[
            pl.BlockSpec((B, D), lambda a, act_p, qsel_p: (0, 0)),
            pl.BlockSpec((1, L, D), lambda a, act_p, qsel_p: (act_p[a], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, B), lambda a, act_p, qsel_p: (a, 0, 0)),
    )
    return pl.pallas_call(
        _cmajor_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((A, L, B), jnp.float32),
        interpret=interpret,
    )(safe, qsel_i, queries, postings)
