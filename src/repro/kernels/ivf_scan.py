"""Fused posting-list scan — the Helmsman serving hot path as Pallas kernels.

Paper (§4.2): cluster reads are fixed-size, batched, dependency-free; SPDK
bypasses the kernel so one PCIe doorbell serves a whole batch.  TPU-native
adaptation: the posting tensor lives in HBM and the Pallas grid pipeline
streams one posting block per grid step into VMEM (double-buffered DMA — the
"doorbell batch").  The kernels in this module differ in what they send BACK
to HBM:

* ``ivf_scan`` / ``ivf_scan_clustermajor`` — legacy full-distance kernels.
  They write the entire (B, P, L) / (A, L, B) distance tensor to HBM, which
  the frontend then re-reads to run a global top-k.  Kept for comparison and
  for consumers that want raw distances.

* ``ivf_scan_topk`` — the candidate-compressed serving data path (default).
  Grid/scratch design:

    - **Query tiling.**  Queries are tiled into blocks of ``bq`` rows; the
      grid is ``(B/bq, bq*P)``.  Each grid step DMAs ONE posting block
      (L, D) and distances it against the whole query tile with a single
      (bq, D) x (D, L) MXU matmul — not the (1, D) matvec of the legacy
      query-major kernel.

    - **Probe plan.**  ``plan_tile_probes`` (host/jnp, jittable) flattens and
      SORTS each tile's cluster list, so duplicate clusters (probe overlap
      across the tile — §6.2 "transient query bursts target the same
      clusters") land on adjacent grid steps: Pallas skips the HBM->VMEM DMA
      when the block index repeats, and the per-query selection mask ``qsel``
      routes one block's distances to every query in the tile that probed it.
      Dead slots (duplicates / masked probes) have an all-false ``qsel``.

    - **In-VMEM running top-k.**  The (bq, k2) candidate block is the
      kernel's accumulator: the output BlockSpec maps every probe step of a
      tile to the same block, so it stays resident in VMEM across the whole
      probe dimension (the standard revisited-output accumulation pattern)
      and is flushed to HBM exactly once per tile.  Each step merges the
      fresh (bq, L) distance tile into the accumulator with a k2-pass
      min-extraction that also suppresses duplicate ids (closure duplicates),
      so the emitted candidates are unique-by-id with per-id MIN distance —
      i.e. exactly the first k2 rows of the legacy dedup-top-k.

    - **In-kernel id resolution.**  The global id row (posting_ids) is a
      blocked input indexed by the same block table, so ids never materialize
      as a (B, P, L) gather in HBM either.

  HBM writeback per query drops from P*L*(4+4) bytes (distances + gathered
  ids) to k2*(4+4) bytes — O(P*L/k) compression (≥ 100x at P=64, L=128,
  k=10).  This is the §4.2 "no redundant copies between engine and device"
  claim re-expressed for the HBM<->VMEM hierarchy: what crosses the memory
  boundary is the answer, not the intermediate.

The data-dependent block index (which cluster to DMA) uses Pallas scalar
prefetch: the per-tile block table is a scalar-prefetch operand consumed by
the BlockSpec index_map — the same mechanism as paged-attention block tables.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# legacy full-distance kernels
# --------------------------------------------------------------------------
def _qmajor_kernel(cids_ref, mask_ref, q_ref, post_ref, o_ref):
    b = pl.program_id(0)
    p = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)            # (1, D)
    blk = post_ref[0].astype(jnp.float32)         # (L, D)
    # ||q||^2 - 2 q.blk^T + ||blk||^2  -> (1, L)
    d = (
        jnp.sum(q * q)
        - 2.0 * jax.lax.dot_general(
            q, blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        + jnp.sum(blk * blk, axis=1)[None, :]
    )
    d = jnp.maximum(d, 0.0)
    live = mask_ref[b, p] > 0
    o_ref[...] = jnp.where(live, d[:, None, :], jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_scan(
    postings: jax.Array,   # (C, L, D)
    cids: jax.Array,       # (B, P) int32
    mask: jax.Array,       # (B, P) bool
    queries: jax.Array,    # (B, D)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, P, L) f32 distances; masked probes +inf.  (Legacy path.)"""
    C, L, D = postings.shape
    B, P = cids.shape
    safe_cids = jnp.clip(cids, 0, C - 1).astype(jnp.int32)
    mask_i = mask.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, p, cids_p, mask_p: (b, 0)),
            pl.BlockSpec((1, L, D), lambda b, p, cids_p, mask_p: (cids_p[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L), lambda b, p, cids_p, mask_p: (b, p, 0)),
    )
    return pl.pallas_call(
        _qmajor_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P, L), jnp.float32),
        interpret=interpret,
    )(safe_cids, mask_i, queries, postings)


def _cmajor_kernel(active_ref, qsel_ref, q_ref, post_ref, o_ref):
    a = pl.program_id(0)
    blk = post_ref[...].astype(jnp.float32)[0]    # (L, D)
    q = q_ref[...].astype(jnp.float32)            # (B, D)
    d = (
        jnp.sum(blk * blk, axis=1)[:, None]
        - 2.0 * jax.lax.dot_general(
            blk, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        + jnp.sum(q * q, axis=1)[None, :]
    )                                             # (L, B) — one MXU matmul
    d = jnp.maximum(d, 0.0)
    sel = qsel_ref[a, :][None, :] > 0             # (1, B)
    o_ref[...] = jnp.where(sel, d, jnp.inf)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_scan_clustermajor(
    postings: jax.Array,   # (C, L, D)
    active: jax.Array,     # (A,) int32
    qsel: jax.Array,       # (A, B) bool
    queries: jax.Array,    # (B, D)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns (A, L, B) f32 distances; unselected (cluster, query) pairs +inf."""
    C, L, D = postings.shape
    A = active.shape[0]
    B = queries.shape[0]
    safe = jnp.clip(active, 0, C - 1).astype(jnp.int32)
    qsel_i = qsel.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(A,),
        in_specs=[
            pl.BlockSpec((B, D), lambda a, act_p, qsel_p: (0, 0)),
            pl.BlockSpec((1, L, D), lambda a, act_p, qsel_p: (act_p[a], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, B), lambda a, act_p, qsel_p: (a, 0, 0)),
    )
    return pl.pallas_call(
        _cmajor_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((A, L, B), jnp.float32),
        interpret=interpret,
    )(safe, qsel_i, queries, postings)


# --------------------------------------------------------------------------
# fused in-kernel top-k (the candidate-compressed serving data path)
# --------------------------------------------------------------------------
def plan_tile_probes(
    cids: jax.Array,   # (B, P) int32 — per-query probe cluster ids
    mask: jax.Array,   # (B, P) bool — live probes
    bq: int,
    n_clusters: int,
    *,
    tile_chunk: int = 0,   # 0 = auto: bound the membership intermediate
) -> tuple[jax.Array, jax.Array]:
    """Build the per-tile block table + query-selection mask.

    Flattens each query tile's (bq, P) probe list to S = bq*P slots, sorts by
    cluster id (dead probes sort to the end), and keeps only the FIRST
    occurrence of each cluster live.  Returns

      tile_cids (B/bq, S) int32 — sorted cluster per grid step (duplicates
        adjacent, so the Pallas pipeline skips the repeat DMAs),
      qsel      (B/bq, S, bq) int32 — qsel[t, s, j] != 0 iff query j of tile
        t probes cluster tile_cids[t, s] (any live probe slot).

    A (query, cluster) pair probed more than once contributes a single scan,
    which matches the dedup-top-k semantics downstream.

    The membership test materializes an O(S·bq·P) boolean per tile; at the
    runtime batcher's large coalesced batches (B >= 1e4) the full
    (nb, S, bq, P) intermediate would be hundreds of MB, so tiles are
    processed in chunks of ``tile_chunk`` (auto-sized to keep each chunk's
    intermediate under ~16M elements).  Chunking is over the tile dim only —
    per-tile outputs are independent — so chunked and one-shot plans are
    bit-identical.
    """
    B, P = cids.shape
    nb = B // bq
    s_len = bq * P
    cl = jnp.clip(cids, 0, n_clusters - 1).astype(jnp.int32)
    live = jnp.asarray(mask, bool) & (cids >= 0)
    key = jnp.where(live, cl, n_clusters).reshape(nb, s_len)
    sc = jnp.sort(key, axis=1)                                   # (nb, S)
    uniq = jnp.concatenate(
        [jnp.ones((nb, 1), bool), sc[:, 1:] != sc[:, :-1]], axis=1
    ) & (sc < n_clusters)
    cl3 = cl.reshape(nb, bq, P)
    lv3 = live.reshape(nb, bq, P)
    if tile_chunk <= 0:
        per_tile = s_len * bq * P
        tile_chunk = max(1, (1 << 24) // max(per_tile, 1))
    qsel_chunks = []
    for lo in range(0, nb, tile_chunk):
        hi = min(lo + tile_chunk, nb)
        member = jnp.any(
            (cl3[lo:hi, None, :, :] == sc[lo:hi, :, None, None])
            & lv3[lo:hi, None, :, :],
            axis=-1,
        )                                                        # (c, S, bq)
        qsel_chunks.append(
            (member & uniq[lo:hi, :, None]).astype(jnp.int32)
        )
    qsel = (qsel_chunks[0] if len(qsel_chunks) == 1
            else jnp.concatenate(qsel_chunks, axis=0))
    tile_cids = jnp.minimum(sc, n_clusters - 1).astype(jnp.int32)
    return tile_cids, qsel


def _extract_topk(cat_d: jax.Array, cat_i: jax.Array, k2: int):
    """k2-pass min-extraction with duplicate-id suppression.

    cat_d, cat_i: (bq, n).  Returns ((bq, k2) dists ascending, (bq, k2) ids);
    exhausted slots are (+inf, -1).  Each pass takes the global min, emits it,
    and kills every remaining entry carrying the same id — so the output is
    unique-by-id with the per-id MIN distance (dedup-top-k semantics; closure
    duplicates of one vector collapse to a single candidate).
    """
    bq, n = cat_d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, n), 1)
    out_d, out_i = [], []
    for _ in range(k2):
        m = jnp.min(cat_d, axis=1, keepdims=True)                 # (bq, 1)
        pos = jnp.min(jnp.where(cat_d == m, col, n), axis=1, keepdims=True)
        hit = col == pos                                          # one-hot
        pid = jnp.sum(jnp.where(hit, cat_i, 0), axis=1, keepdims=True)
        ok = jnp.isfinite(m)
        out_d.append(jnp.where(ok, m, jnp.inf)[:, 0])
        out_i.append(jnp.where(ok, pid, -1)[:, 0])
        kill = hit | ((cat_i == pid) & (pid >= 0) & ok)
        cat_d = jnp.where(kill, jnp.inf, cat_d)
    return jnp.stack(out_d, axis=1), jnp.stack(out_i, axis=1).astype(jnp.int32)


def _qtile_topk_kernel(tc_ref, q_ref, pids_ref, qsel_ref, post_ref,
                       od_ref, oi_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, jnp.inf, od_ref.dtype)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, oi_ref.dtype)

    q = q_ref[...].astype(jnp.float32)                  # (bq, D)
    blk = post_ref[0].astype(jnp.float32)               # (L, D)
    d = (
        jnp.sum(q * q, axis=1, keepdims=True)
        - 2.0 * jax.lax.dot_general(
            q, blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        + jnp.sum(blk * blk, axis=1)[None, :]
    )                                                   # (bq, L) — one MXU op
    d = jnp.maximum(d, 0.0)
    bq = d.shape[0]
    sel = jnp.reshape(qsel_ref[...], (bq, 1)) > 0       # (bq, 1)
    ids = jnp.broadcast_to(pids_ref[...], d.shape).astype(jnp.int32)
    d = jnp.where(sel & (ids >= 0), d, jnp.inf)
    cat_d = jnp.concatenate([od_ref[...], d], axis=1)
    cat_i = jnp.concatenate([oi_ref[...], ids], axis=1)
    nd, ni = _extract_topk(cat_d, cat_i, od_ref.shape[-1])
    od_ref[...] = nd
    oi_ref[...] = ni


@functools.partial(jax.jit, static_argnames=("k2", "bq", "interpret"))
def ivf_scan_topk(
    postings: jax.Array,     # (C, L, D)
    posting_ids: jax.Array,  # (C, L) int32, -1 = pad slot
    cids: jax.Array,         # (B, P) int32
    mask: jax.Array,         # (B, P) bool
    queries: jax.Array,      # (B, D)
    *,
    k2: int,
    bq: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused scan + in-kernel top-k2: returns ((B, k2) dists, (B, k2) ids).

    Candidates are unique-by-id, ascending by distance, padded with
    (+inf, -1).  Only (B, k2) crosses the pallas_call boundary — never the
    (B, P, L) distance tensor.
    """
    C, L, D = postings.shape
    B, P = cids.shape
    padb = (-B) % bq
    if padb:
        queries = jnp.pad(queries, ((0, padb), (0, 0)))
        cids = jnp.pad(cids, ((0, padb), (0, 0)))
        mask = jnp.pad(jnp.asarray(mask, bool), ((0, padb), (0, 0)))
    bp = B + padb
    nb = bp // bq
    s_len = bq * P
    tile_cids, qsel = plan_tile_probes(cids, mask, bq, C)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, s_len),
        in_specs=[
            pl.BlockSpec((bq, D), lambda t, s, tc: (t, 0)),
            pl.BlockSpec((1, L), lambda t, s, tc: (tc[t, s], 0)),
            pl.BlockSpec((1, 1, bq), lambda t, s, tc: (t, s, 0)),
            pl.BlockSpec((1, L, D), lambda t, s, tc: (tc[t, s], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k2), lambda t, s, tc: (t, 0)),
            pl.BlockSpec((bq, k2), lambda t, s, tc: (t, 0)),
        ],
    )
    od, oi = pl.pallas_call(
        _qtile_topk_kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((bp, k2), jnp.float32),
            jax.ShapeDtypeStruct((bp, k2), jnp.int32),
        ),
        interpret=interpret,
    )(tile_cids, queries, posting_ids.astype(jnp.int32), qsel, postings)
    return od[:B], oi[:B]
