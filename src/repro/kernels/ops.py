"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` (Pallas executes
the kernel body with jnp semantics); on TPU they lower to Mosaic.  Callers
never pass ``interpret`` themselves — ``_interp()`` resolves it per backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ivf_scan as _ivf
from . import pairwise_l2 as _pw
from . import ref as ref


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_l2(a, b, *, bn: int = 128, bm: int = 128, bd: int = 512):
    """Pairwise squared L2 (N, D) x (M, D) -> (N, M)."""
    return _pw.pairwise_l2(a, b, bn=bn, bm=bm, bd=bd, interpret=_interp())


def kmeans_assign(x, centroids, *, chunk: int = 16384):
    """argmin-distance assignment + distances (the k-means E-step).

    Returns (assign (N,), min_dist (N,)). Chunked over N to bound the
    (chunk, C) distance tile.  On TPU the tile is the pairwise_l2 Pallas
    kernel; elsewhere the jnp oracle (interpret-mode grids are a correctness
    harness, not a fast path).
    """
    n = x.shape[0]
    tile = pairwise_l2 if jax.default_backend() == "tpu" else _ref_tile
    outs_a, outs_d = [], []
    for s in range(0, n, chunk):
        d = tile(x[s:s + chunk], centroids)
        outs_a.append(jnp.argmin(d, axis=1).astype(jnp.int32))
        outs_d.append(jnp.min(d, axis=1))
    return jnp.concatenate(outs_a), jnp.concatenate(outs_d)


_ASSIGN_VMEM_FLOATS = 1 << 21   # ~8 MiB f32 working set (half of v5e VMEM,
                                # leaving headroom for grid double-buffering)
_ASSIGN_BN = 512                # point-block rows per grid step


def kmeans_assign_update_tile(x, centroids):
    """Single-tile fused assign+accumulate (jittable; kernel on TPU, jnp
    oracle elsewhere).  Returns (assign, min_dist, sums, counts) — the
    building block of kmeans_assign_update and kmeans_sharded_step.

    The kernel's per-step VMEM working set is the whole (Kp, Dp) centroid
    block PLUS the revisited (Kp, Dp) sums accumulator PLUS the (BN, Kp)
    distance and one-hot tiles and the (BN, Dp) point block (K-chunking is
    impossible without a second pass: the argmin must be global before
    accumulation).  Shapes whose estimate exceeds the budget fall back to
    the jnp oracle instead of failing Mosaic compilation."""
    k, d = centroids.shape
    kp = ((k + 127) // 128) * 128
    dp = ((d + 127) // 128) * 128
    need = 2 * kp * dp + 2 * _ASSIGN_BN * kp + _ASSIGN_BN * dp
    if jax.default_backend() == "tpu" and need <= _ASSIGN_VMEM_FLOATS:
        from . import kmeans_assign as _km
        return _km.kmeans_assign_update(x, centroids, bn=_ASSIGN_BN,
                                        interpret=False)
    return _ref_assign_tile(x, centroids)


def kmeans_assign_update(x, centroids, *, chunk: int = 16384):
    """Fused Lloyd iteration: E-step argmin + M-step accumulation in one pass.

    Returns (assign (N,), min_dist (N,), sums (K, D) f32, counts (K,) i32).
    Chunked over N like kmeans_assign; per-centroid partial sums/counts from
    each chunk are folded on device, so the (N, K) distance matrix AND the
    host scatter-add both disappear — only (K, D) + (K,) + 2*(N,) cross HBM.
    Per-chunk counts are exact small integers in f32 (chunk <= 2^24); the
    cross-chunk fold is integer, so counts stay exact at any corpus size.
    """
    n = x.shape[0]
    outs_a, outs_m = [], []
    sums = None
    counts = None
    for s in range(0, n, chunk):
        a, md, ps, pc = kmeans_assign_update_tile(x[s:s + chunk], centroids)
        pc = jnp.round(pc).astype(jnp.int32)
        outs_a.append(a)
        outs_m.append(md)
        sums = ps if sums is None else sums + ps
        counts = pc if counts is None else counts + pc
    return (jnp.concatenate(outs_a), jnp.concatenate(outs_m), sums, counts)


def kmeans_mstep(sums, counts, reseed):
    """Fused M-step finisher: new centroids from (sums, counts) with empty
    clusters reseeded at the worst-served points (kernel on TPU, jnp oracle
    elsewhere — the same routing rule as kmeans_assign_update_tile).

    The kernel's working set is three (Kp, Dp) blocks plus the (Kp, Kp)
    rank/selection tiles; shapes whose estimate exceeds the VMEM budget fall
    back to the oracle instead of failing Mosaic compilation.
    """
    k, d = sums.shape
    kp = ((k + 127) // 128) * 128
    dp = ((d + 127) // 128) * 128
    need = 3 * kp * dp + 2 * kp * kp
    if jax.default_backend() == "tpu" and need <= _ASSIGN_VMEM_FLOATS:
        from . import kmeans_mstep as _km_mstep
        return _km_mstep.kmeans_mstep(sums, counts, reseed, interpret=False)
    return _ref_mstep_tile(sums, counts, reseed)


@jax.jit
def _ref_mstep_tile(sums, counts, reseed):
    return ref.kmeans_mstep_ref(sums, counts, reseed)


@jax.jit
def _ref_tile(a, b):
    return ref.pairwise_l2_ref(a, b)


@jax.jit
def _ref_assign_tile(x, centroids):
    return ref.kmeans_assign_update_ref(x, centroids)


def ivf_scan(postings, cids, mask, queries):
    """Fused posting gather + L2 scan. (B, P, L) f32, masked probes +inf."""
    return _ivf.ivf_scan(postings, cids, mask, queries, interpret=_interp())


def ivf_scan_clustermajor(postings, active, qsel, queries):
    """Cluster-major fused scan. (A, L, B) f32."""
    return _ivf.ivf_scan_clustermajor(
        postings, active, qsel, queries, interpret=_interp()
    )


def ivf_scan_q8(q8, scale, norm2, centroids, cids, mask, queries):
    """Fused int8-residual posting scan (hillclimb it.3 hot path)."""
    from . import ivf_scan_q8 as _q8
    return _q8.ivf_scan_q8(q8, scale, norm2, centroids, cids, mask, queries,
                           interpret=_interp())


def ivf_scan_topk(postings, posting_ids, cids, mask, queries, *, k2, bq=8):
    """Candidate-compressed scan: fused gather + L2 + in-kernel top-k2.

    Returns ((B, k2) dists, (B, k2) ids) — the (B, P, L) distance tensor
    never crosses the pallas_call boundary."""
    return _ivf.ivf_scan_topk(postings, posting_ids, cids, mask, queries,
                              k2=k2, bq=bq, interpret=_interp())


def ivf_scan_q8_topk(q8, scale, norm2, centroids, posting_ids, cids, mask,
                     queries, *, k2, bq=8):
    """Candidate-compressed int8-residual scan (see ivf_scan_topk)."""
    from . import ivf_scan_q8 as _q8
    return _q8.ivf_scan_q8_topk(q8, scale, norm2, centroids, posting_ids,
                                cids, mask, queries, k2=k2, bq=bq,
                                interpret=_interp())
