"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` (Pallas executes
the kernel body with jnp semantics); on TPU they lower to Mosaic.  Callers
never pass ``interpret`` themselves — ``_interp()`` resolves it per backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ivf_scan as _ivf
from . import pairwise_l2 as _pw
from . import ref as ref


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_l2(a, b, *, bn: int = 128, bm: int = 128, bd: int = 512):
    """Pairwise squared L2 (N, D) x (M, D) -> (N, M)."""
    return _pw.pairwise_l2(a, b, bn=bn, bm=bm, bd=bd, interpret=_interp())


def kmeans_assign(x, centroids, *, chunk: int = 16384):
    """argmin-distance assignment + distances (the k-means E-step).

    Returns (assign (N,), min_dist (N,)). Chunked over N to bound the
    (chunk, C) distance tile.  On TPU the tile is the pairwise_l2 Pallas
    kernel; elsewhere the jnp oracle (interpret-mode grids are a correctness
    harness, not a fast path).
    """
    n = x.shape[0]
    tile = pairwise_l2 if jax.default_backend() == "tpu" else _ref_tile
    outs_a, outs_d = [], []
    for s in range(0, n, chunk):
        d = tile(x[s:s + chunk], centroids)
        outs_a.append(jnp.argmin(d, axis=1).astype(jnp.int32))
        outs_d.append(jnp.min(d, axis=1))
    return jnp.concatenate(outs_a), jnp.concatenate(outs_d)


@jax.jit
def _ref_tile(a, b):
    return ref.pairwise_l2_ref(a, b)


def ivf_scan(postings, cids, mask, queries):
    """Fused posting gather + L2 scan. (B, P, L) f32, masked probes +inf."""
    return _ivf.ivf_scan(postings, cids, mask, queries, interpret=_interp())


def ivf_scan_clustermajor(postings, active, qsel, queries):
    """Cluster-major fused scan. (A, L, B) f32."""
    return _ivf.ivf_scan_clustermajor(
        postings, active, qsel, queries, interpret=_interp()
    )


def ivf_scan_q8(q8, scale, norm2, centroids, cids, mask, queries):
    """Fused int8-residual posting scan (hillclimb it.3 hot path)."""
    from . import ivf_scan_q8 as _q8
    return _q8.ivf_scan_q8(q8, scale, norm2, centroids, cids, mask, queries,
                           interpret=_interp())


def ivf_scan_topk(postings, posting_ids, cids, mask, queries, *, k2, bq=8):
    """Candidate-compressed scan: fused gather + L2 + in-kernel top-k2.

    Returns ((B, k2) dists, (B, k2) ids) — the (B, P, L) distance tensor
    never crosses the pallas_call boundary."""
    return _ivf.ivf_scan_topk(postings, posting_ids, cids, mask, queries,
                              k2=k2, bq=bq, interpret=_interp())


def ivf_scan_q8_topk(q8, scale, norm2, centroids, posting_ids, cids, mask,
                     queries, *, k2, bq=8):
    """Candidate-compressed int8-residual scan (see ivf_scan_topk)."""
    from . import ivf_scan_q8 as _q8
    return _q8.ivf_scan_q8_topk(q8, scale, norm2, centroids, posting_ids,
                                cids, mask, queries, k2=k2, bq=bq,
                                interpret=_interp())
