"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_l2_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(N, D) x (M, D) -> (N, M) squared L2, f32 accumulation."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T
    return jnp.maximum(a2 - 2.0 * (a @ b.T) + b2, 0.0)


def assign_distances_f64(x, centroids, assign):
    """Float64 point-to-assigned-centroid squared distances (numpy).

    The shared core of every tie-tolerant parity check: when two assign
    paths disagree on a point, both picks must realize ~the same minimum —
    callers compare assign_distances_f64(..., a) against (..., b) under
    their own tolerance."""
    import numpy as np

    xf = np.asarray(x, np.float64)
    cf = np.asarray(centroids, np.float64)
    return ((xf - cf[np.asarray(assign)]) ** 2).sum(-1)


def kmeans_assign_update_ref(
    x: jax.Array,          # (N, D)
    centroids: jax.Array,  # (K, D)
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused assign-and-accumulate kernel.

    Returns (assign (N,) i32, min_dist (N,) f32, sums (K, D) f32,
    counts (K,) f32) — the exact output contract of
    kernels.kmeans_assign.kmeans_assign_update.  Distances go through
    pairwise_l2_ref, so the argmin is bit-identical to the unfused
    ops.kmeans_assign path on the same backend.
    """
    d = pairwise_l2_ref(x, centroids)                    # (N, K)
    a = jnp.argmin(d, axis=1).astype(jnp.int32)
    md = jnp.min(d, axis=1)
    oh = jax.nn.one_hot(a, centroids.shape[0], dtype=jnp.float32)
    sums = jax.lax.dot_general(                          # (K, D)
        oh, x.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    counts = jnp.sum(oh, axis=0)
    return a, md, sums, counts


def kmeans_mstep_ref(
    sums: jax.Array,       # (K, D) f32
    counts: jax.Array,     # (K,)
    reseed: jax.Array,     # (K, D) worst-served points, descending min-dist
) -> jax.Array:
    """Oracle for the fused M-step kernel: division + empty-cluster reseed.

    Empty cluster k takes reseed[rank(k)] where rank(k) counts the empty
    clusters before k (the e-th empty cluster gets the e-th worst-served
    point — the host reseed rule of build/kmeans.kmeans).
    """
    counts = counts.astype(jnp.float32)
    empty = counts <= 0.0
    rank = jnp.cumsum(empty.astype(jnp.int32)) - empty.astype(jnp.int32)
    mean = sums.astype(jnp.float32) / jnp.maximum(counts, 1.0)[:, None]
    return jnp.where(empty[:, None], reseed.astype(jnp.float32)[rank], mean)


def ivf_scan_ref(
    postings: jax.Array,   # (C, L, D)
    cids: jax.Array,       # (B, P) int32 (clamped valid)
    mask: jax.Array,       # (B, P) bool — True = scan this cluster
    queries: jax.Array,    # (B, D)
) -> jax.Array:
    """Gather selected posting lists and compute squared L2 distances.

    Returns (B, P, L) f32; masked probes are +inf.
    """
    q = queries.astype(jnp.float32)
    gathered = postings[jnp.clip(cids, 0, postings.shape[0] - 1)]  # (B,P,L,D)
    gathered = gathered.astype(jnp.float32)
    diff2 = (
        jnp.sum(q * q, axis=-1)[:, None, None]
        - 2.0 * jnp.einsum("bd,bpld->bpl", q, gathered)
        + jnp.sum(gathered * gathered, axis=-1)
    )
    diff2 = jnp.maximum(diff2, 0.0)
    return jnp.where(mask[:, :, None], diff2, jnp.inf)


def ivf_scan_topk_ref(
    postings: jax.Array,     # (C, L, D)
    posting_ids: jax.Array,  # (C, L) int32, -1 = pad slot
    cids: jax.Array,         # (B, P) int32
    mask: jax.Array,         # (B, P) bool
    queries: jax.Array,      # (B, D)
    k2: int,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused-topk kernel: full scan then dedup-top-k2.

    Returns ((B, k2) dists ascending, (B, k2) global ids), unique-by-id with
    per-id min distance, padded with (+inf, -1) — the exact candidate
    contract of kernels.ivf_scan.ivf_scan_topk (up to tie ordering).
    """
    from repro.core.distance import dedup_topk  # lazy: avoid import cycle

    d = ivf_scan_ref(postings, cids, mask, queries)               # (B, P, L)
    ids = posting_ids[jnp.clip(cids, 0, postings.shape[0] - 1)]   # (B, P, L)
    d = jnp.where(ids < 0, jnp.inf, d)
    b = queries.shape[0]
    return dedup_topk(d.reshape(b, -1), ids.reshape(b, -1), k2)


def ivf_scan_q8_topk_ref(
    q8: jax.Array,           # (C, L, D) int8 residual codes
    scale: jax.Array,        # (C, 1, 1) f32
    norm2: jax.Array,        # (C, L) f32
    centroids: jax.Array,    # (C, D) f32
    posting_ids: jax.Array,  # (C, L) int32
    cids: jax.Array,         # (B, P) int32
    mask: jax.Array,         # (B, P) bool
    queries: jax.Array,      # (B, D)
    k2: int,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused-topk q8 kernel (same candidate contract)."""
    from repro.core.distance import dedup_topk  # lazy: avoid import cycle

    q = queries.astype(jnp.float32)
    safe = jnp.clip(cids, 0, q8.shape[0] - 1)
    g8 = q8[safe].astype(jnp.float32)                    # (B, P, L, D)
    s = scale[safe][:, :, :, 0]                          # (B, P, 1)
    qc = q[:, None, :] - centroids[safe]                 # (B, P, D)
    cross = jnp.einsum("bpd,bpld->bpl", qc, g8)
    d = jnp.sum(qc * qc, axis=-1)[:, :, None] - 2.0 * s * cross + norm2[safe]
    d = jnp.maximum(d, 0.0)
    d = jnp.where(mask[:, :, None], d, jnp.inf)
    ids = posting_ids[safe]                              # (B, P, L)
    d = jnp.where(ids < 0, jnp.inf, d)
    b = queries.shape[0]
    return dedup_topk(d.reshape(b, -1), ids.reshape(b, -1), k2)


def ivf_scan_clustermajor_ref(
    postings: jax.Array,   # (C, L, D)
    active: jax.Array,     # (A,) int32 cluster ids to visit (union of probes)
    qsel: jax.Array,       # (A, B) bool — query b probes active cluster a
    queries: jax.Array,    # (B, D)
) -> jax.Array:
    """Cluster-major scan (beyond-paper MXU-friendly variant).

    Returns (A, L, B) f32 distances, +inf where the query did not select the
    cluster.
    """
    q = queries.astype(jnp.float32)                      # (B, D)
    g = postings[jnp.clip(active, 0, postings.shape[0] - 1)].astype(jnp.float32)
    d = (
        jnp.sum(g * g, axis=-1)[:, :, None]
        - 2.0 * jnp.einsum("ald,bd->alb", g, q)
        + jnp.sum(q * q, axis=-1)[None, None, :]
    )
    d = jnp.maximum(d, 0.0)
    return jnp.where(qsel[:, None, :], d, jnp.inf)
