"""Fused Lloyd M-step: centroid division + empty-cluster reseed, in-kernel.

The assign-and-accumulate kernel (kernels/kmeans_assign.py) already keeps the
(N, K) distance matrix in VMEM and emits per-centroid sums + counts — but the
pre-PR-4 ``kmeans`` loop still pulled those to HOST to finish the iteration:
a numpy division for the means and an argsort-based reseed of empty clusters.
That readback forces a device->host->device round trip per Lloyd iteration
and serializes the loop on the host.

This kernel folds the remainder of the iteration on device:

    new_cents[k] = sums[k] / counts[k]                     counts[k] > 0
                 = reseed[rank(k)]                         counts[k] == 0

where ``reseed`` holds the worst-served points (largest min-dist, the same
rule as the host path) and ``rank(k)`` is k's position among the empty
clusters — the e-th empty cluster takes the e-th worst-served point.

Everything stays lane-oriented so no transposes hit Mosaic:

* counts arrive as a (Kp, 1) column;
* the exclusive count of preceding empties is a strict-lower-triangular
  (Kp, Kp) x (Kp, 1) matmul (MXU, not a scan);
* the reseed gather is a one-hot selection matmul sel @ reseed, exactly like
  the assign kernel's one-hot M-step fold.

Padding contract: padded K rows have count 0 but are masked out of ``empty``
(they consume no reseed ranks and are sliced off); padded D columns are zero
through the division and the selection matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(s_ref, cnt_ref, r_ref, o_ref, *, n_cents: int):
    s = s_ref[...]                                      # (Kp, Dp) f32 sums
    cnt = cnt_ref[...]                                  # (Kp, 1) f32 counts
    r = r_ref[...]                                      # (Kp, Dp) f32 reseeds
    kp = s.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (kp, 1), 0)
    empty = (cnt <= 0.0) & (row < n_cents)              # (Kp, 1)
    e = empty.astype(jnp.float32)
    i = jax.lax.broadcasted_iota(jnp.int32, (kp, kp), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (kp, kp), 1)
    ltri = (j < i).astype(jnp.float32)                  # ltri[k, j] = [j < k]
    rank = jax.lax.dot_general(                         # (Kp, 1) — MXU, not a
        ltri, e, (((1,), (0,)), ((), ())),              # sequential scan
        preferred_element_type=jnp.float32,
    )
    sel = ((j == rank.astype(jnp.int32)) & empty).astype(jnp.float32)
    reseeded = jax.lax.dot_general(                     # (Kp, Dp) one-hot gather
        sel, r, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    mean = s / jnp.maximum(cnt, 1.0)
    o_ref[...] = jnp.where(empty, reseeded, mean)


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("interpret",))
def kmeans_mstep(
    sums: jax.Array,       # (K, D) f32 per-centroid sums
    counts: jax.Array,     # (K,) f32/i32 per-centroid counts
    reseed: jax.Array,     # (K, D) f32 reseed candidates, worst-served first
    *,
    interpret: bool = False,
) -> jax.Array:
    """Finish one Lloyd iteration on device; returns new centroids (K, D).

    ``reseed`` must hold >= (number of empty clusters) rows ordered by
    descending min-dist; passing the top-K worst-served points (one gather of
    ``x[jax.lax.top_k(min_dist, K).indices]``) always satisfies that bound.
    Ties in min-dist resolve by lowest point index (jax.lax.top_k order) —
    the canonical semantics the host reference is tested against.
    """
    k, d = sums.shape
    kp = _ceil_mult(k, 128)
    dp = _ceil_mult(d, 128)
    sp = jnp.pad(sums.astype(jnp.float32), ((0, kp - k), (0, dp - d)))
    cp = jnp.pad(counts.astype(jnp.float32).reshape(k, 1), ((0, kp - k), (0, 0)))
    rp = jnp.pad(reseed.astype(jnp.float32), ((0, kp - k), (0, dp - d)))
    out = pl.pallas_call(
        functools.partial(_kernel, n_cents=k),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((kp, dp), lambda i: (0, 0)),
            pl.BlockSpec((kp, 1), lambda i: (0, 0)),
            pl.BlockSpec((kp, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((kp, dp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, dp), jnp.float32),
        interpret=interpret,
    )(sp, cp, rp)
    return out[:k, :d]
