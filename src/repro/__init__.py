"""repro package init — compatibility shims for the pinned container jax.

The codebase targets the current jax API surface; the container pins
jax 0.4.37 where ``shard_map`` still lives in jax.experimental and spells the
replication-check kwarg ``check_rep``.  Installing the alias here (every
module of this package imports through here) keeps call sites on the modern
``jax.shard_map(..., check_vma=...)`` spelling with no per-module guards.
"""
import jax as _jax

if not hasattr(_jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f, mesh=None, in_specs=None, out_specs=None,
                          check_vma=True, **kw):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kw)

    _jax.shard_map = _compat_shard_map
