"""Index data layout: metadata as files, cluster lists as raw blocks (Fig. 10).

``IndexMeta`` is the paper's metadata file — index name, per-cluster physical
location (device id + LBA), pruning-model blob paths, and the centroid index —
small enough to live in DRAM at runtime (it is JSON + npz on the metadata
device).

``plan_striping`` converts an arena extent map into the permutation that
shards the posting tensor over the ``model`` mesh axis: cluster i is placed on
mesh shard ``extent.device % n_shards``, and within a shard the clusters are
densely packed in extent order.  The serving engine looks up clusters through
``shard_of``/``slot_of`` so the logical cluster id never needs to equal its
physical position — exactly the indirection the paper's metadata map provides.

``ReplicaMap`` implements the §6.2 hot-spot mitigation: a few redundant copies
of (hot) cluster lists placed on other devices; query load is hashed across
replicas, and a replica is the fallback when a shard fails (fault tolerance).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from .arena import ChunkArena, Extent, LBA_BYTES


@dataclasses.dataclass
class IndexMeta:
    name: str
    n_clusters: int
    cluster_len: int
    dim: int
    dtype: str
    extents: List[Extent]
    model_files: Dict[str, str] = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d)

    @staticmethod
    def from_json(s: str) -> "IndexMeta":
        d = json.loads(s)
        d["extents"] = [Extent(**e) for e in d["extents"]]
        return IndexMeta(**d)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "IndexMeta":
        with open(path) as f:
            return IndexMeta.from_json(f.read())


@dataclasses.dataclass
class Striping:
    """cluster id -> (shard, slot) placement + inverse permutation.

    ``perm`` reorders the logical posting tensor (C, L, D) so that
    ``postings[perm]`` is shard-major: shard s owns rows
    [s*rows_per_shard, (s+1)*rows_per_shard).  ``cluster_to_row[i]`` is the
    row of logical cluster i after permutation.
    """

    n_shards: int
    rows_per_shard: int
    perm: np.ndarray            # (C_padded,) row -> logical cluster (-1 pad)
    cluster_to_row: np.ndarray  # (C,) logical cluster -> row

    def shard_of(self, cluster: np.ndarray) -> np.ndarray:
        return self.cluster_to_row[cluster] // self.rows_per_shard


def plan_striping(
    n_clusters: int,
    n_shards: int,
    extents: Optional[Sequence[Extent]] = None,
) -> Striping:
    """Plan the shard-major permutation of the posting tensor.

    With an arena extent map, clusters follow their physical device placement
    (device d -> shard d % n_shards).  Without one, round-robin striping (the
    arena's allocation order is round-robin anyway).  Shards are padded to
    equal row counts with -1 (payload rows are duplicated data, masked by
    posting id -1 during search).
    """
    if extents is not None:
        shard_of = np.array([e.device % n_shards for e in extents])
    else:
        shard_of = np.arange(n_clusters) % n_shards
    members = [np.nonzero(shard_of == s)[0] for s in range(n_shards)]
    rows = max(len(m) for m in members)
    perm = np.full(n_shards * rows, -1, dtype=np.int64)
    c2r = np.zeros(n_clusters, dtype=np.int64)
    for s, m in enumerate(members):
        perm[s * rows : s * rows + len(m)] = m
        c2r[m] = s * rows + np.arange(len(m))
    return Striping(n_shards, rows, perm, c2r)


def apply_striping(
    striping: Striping, postings: np.ndarray, posting_ids: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Materialize the shard-major posting tensor ((S*rows, L, D), (S*rows, L)).

    Pad rows replicate row 0's payload with ids=-1 (never contribute)."""
    perm = striping.perm
    safe = np.maximum(perm, 0)
    p = postings[safe]
    ids = np.where(perm[:, None] >= 0, posting_ids[safe], -1)
    return p, ids


@dataclasses.dataclass
class ReplicaMap:
    """Redundant cluster copies across shards (§6.2 die-conflict mitigation
    + shard-failure fallback).

    replicas[i] lists the shards holding cluster i; entry 0 is the primary.
    """

    replicas: np.ndarray  # (C, R) int32, -1 = no replica in that slot

    @property
    def n_replicas(self) -> int:
        return self.replicas.shape[1]

    def route(self, cluster: np.ndarray, salt: np.ndarray) -> np.ndarray:
        """Pick a serving shard per (cluster, query-salt): load balancing by
        hashing across live replica slots."""
        r = self.replicas[cluster]
        n_live = (r >= 0).sum(axis=-1)
        pick = salt % np.maximum(n_live, 1)
        return np.take_along_axis(r, pick[..., None], axis=-1)[..., 0]

    def failover(self, failed_shards: Sequence[int]) -> "ReplicaMap":
        """Return a map with failed shards masked out; clusters whose every
        replica failed keep -1 (reported lost by the caller)."""
        mask = np.isin(self.replicas, np.asarray(failed_shards, dtype=np.int32))
        rep = np.where(mask, -1, self.replicas)
        # compact: primaries first
        order = np.argsort(rep < 0, axis=1, kind="stable")
        return ReplicaMap(np.take_along_axis(rep, order, axis=1))

    def lost_clusters(self) -> np.ndarray:
        return np.nonzero((self.replicas < 0).all(axis=1))[0]


def make_replica_map(
    n_clusters: int,
    n_shards: int,
    striping: Striping,
    hot_clusters: Optional[np.ndarray] = None,
    n_replicas: int = 2,
) -> ReplicaMap:
    """Primary from striping; hot clusters get n_replicas-1 extra copies on
    (primary + j * stride) shards."""
    rep = np.full((n_clusters, n_replicas), -1, dtype=np.int32)
    rep[:, 0] = striping.shard_of(np.arange(n_clusters))
    if hot_clusters is not None and n_shards > 1:
        stride = max(1, n_shards // n_replicas)
        for j in range(1, n_replicas):
            rep[hot_clusters, j] = (rep[hot_clusters, 0] + j * stride) % n_shards
    return ReplicaMap(rep)
