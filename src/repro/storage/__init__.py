from .arena import ChunkArena, Extent, OutOfSpace, LBA_BYTES
from .layout import (
    IndexMeta,
    ReplicaMap,
    Striping,
    apply_striping,
    make_replica_map,
    plan_striping,
)
from .host_tier import (
    FetchEvent,
    QuantizedTieredPostings,
    TieredPostings,
    TierStats,
)
from .flash_tier import FlashStats, FlashTier, ReadEvent
