"""Chunk-based free-list allocator over raw block devices (paper §4.2).

The paper's space-allocation insight: because every cluster list is padded to
a fixed size, SSD space can be managed with a trivial, fragmentation-free
chunk allocator (64 MB chunks by default) instead of a filesystem.  Each index
partitions its chunks into consecutive block ranges sized to one cluster list
and assigns each range to a single cluster, so reading one cluster is one
contiguous I/O on one device.

This module is the host-side bookkeeping tier of the TPU adaptation: the
"devices" are the posting shards (one per `model`-axis device on the serving
mesh, standing in for the 12-SSD array), and the extent map it produces is the
cluster->(shard, offset) layout consumed by ``storage.layout`` when the
posting tensor is sharded.  It also supports multi-index hosting — several
indexes co-resident on one all-flash node — which is how 40 machines replace
35k cores in the deployment (§6.1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

LBA_BYTES = 4096  # logical block size


@dataclasses.dataclass(frozen=True)
class Extent:
    """A contiguous block range on one device: one cluster list."""

    device: int
    lba: int          # first logical block
    n_blocks: int

    @property
    def nbytes(self) -> int:
        return self.n_blocks * LBA_BYTES


class OutOfSpace(RuntimeError):
    pass


class ChunkArena:
    """Unified chunk-based free-list allocator for all indexes on a node.

    Chunks are fixed-size (default 64 MB) regions carved from each device.
    Allocation requests take a cluster-list size and a count; the arena hands
    back extents that never cross a chunk (hence never cross a device), and
    recycles whole chunks when an index is deleted.
    """

    def __init__(
        self,
        n_devices: int,
        device_bytes: int,
        chunk_bytes: int = 64 << 20,
    ):
        if chunk_bytes % LBA_BYTES:
            raise ValueError("chunk_bytes must be LBA-aligned")
        self.n_devices = n_devices
        self.device_bytes = device_bytes
        self.chunk_bytes = chunk_bytes
        self.chunks_per_device = device_bytes // chunk_bytes
        # free list of (device, chunk_idx); device-round-robin order so
        # consecutive allocations stripe across the array (bandwidth)
        self._free: List[Tuple[int, int]] = [
            (d, c)
            for c in range(self.chunks_per_device)
            for d in range(n_devices)
        ]
        self._free.reverse()  # pop() yields round-robin order
        self._owned: Dict[str, List[Tuple[int, int]]] = {}

    # -- stats ---------------------------------------------------------------
    @property
    def free_bytes(self) -> int:
        return len(self._free) * self.chunk_bytes

    @property
    def used_bytes(self) -> int:
        return sum(len(v) for v in self._owned.values()) * self.chunk_bytes

    def indexes(self) -> List[str]:
        return list(self._owned)

    # -- alloc / free ----------------------------------------------------------
    def allocate_index(
        self, name: str, n_clusters: int, cluster_bytes: int
    ) -> List[Extent]:
        """Allocate extents for an index's cluster lists.

        Each extent is LBA-aligned, chunk-resident and device-contiguous.
        Raises OutOfSpace (allocating nothing) if capacity is insufficient.
        """
        if name in self._owned:
            raise ValueError(f"index {name!r} already allocated")
        blocks_per_cluster = -(-cluster_bytes // LBA_BYTES)
        aligned_bytes = blocks_per_cluster * LBA_BYTES
        per_chunk = self.chunk_bytes // aligned_bytes
        if per_chunk == 0:
            raise ValueError("cluster larger than a chunk")
        need_chunks = -(-n_clusters // per_chunk)
        if need_chunks > len(self._free):
            raise OutOfSpace(
                f"{name}: need {need_chunks} chunks, {len(self._free)} free"
            )
        chunks = [self._free.pop() for _ in range(need_chunks)]
        self._owned[name] = chunks
        extents: List[Extent] = []
        for i in range(n_clusters):
            dev, chunk = chunks[i // per_chunk]
            slot = i % per_chunk
            lba = (chunk * self.chunk_bytes + slot * aligned_bytes) // LBA_BYTES
            extents.append(Extent(dev, lba, blocks_per_cluster))
        return extents

    def release_index(self, name: str) -> None:
        """Recycle all chunks of an index (whole-chunk granularity)."""
        chunks = self._owned.pop(name, None)
        if chunks is None:
            raise KeyError(name)
        self._free.extend(reversed(chunks))

    def validate(self) -> None:
        """Invariant check (used by property tests): no chunk double-owned,
        owned + free == total."""
        seen = set()
        for name, chunks in self._owned.items():
            for c in chunks:
                if c in seen:
                    raise AssertionError(f"chunk {c} owned twice ({name})")
                seen.add(c)
        for c in self._free:
            if c in seen:
                raise AssertionError(f"chunk {c} both free and owned")
            seen.add(c)
        total = self.n_devices * self.chunks_per_device
        if len(seen) != total:
            raise AssertionError(f"chunk leak: {len(seen)} != {total}")
