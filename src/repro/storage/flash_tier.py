"""mmap-backed simulated flash tier for full-precision re-rank reads.

The quantized serving default (ISSUE 8 / paper §2 cost thesis) demotes the
f32 vectors out of host DRAM: the hot tier keeps only the int8-residual
payload (storage/host_tier.QuantizedTieredPostings), and the full-precision
copy lives here — a file-backed ``np.memmap`` standing in for the raw-block
SSD tier, addressed by GLOBAL vector id (re-rank candidates arrive as
fused-topk ids, not cluster slots, so the flash layout is id-major rather
than cluster-major).

Reads are stamped (``ReadEvent``) the same way ``TieredPostings`` stamps
fetches, so the serving pipeline can *measure* that re-rank I/O for batch i
lands inside batch i+1's scan-in-flight window (the FusionANNS/Kioxia
overlap argument) instead of asserting it.  Space is accounted against the
shared :class:`~repro.storage.arena.ChunkArena` in row-block extents when an
arena is given — the flash tier is a tenant of the same raw-block device
budget as the posting shards.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import List, Optional

import numpy as np

from .arena import ChunkArena, Extent, LBA_BYTES


@dataclasses.dataclass
class ReadEvent:
    """Wall-clock stamps + accounting of one flash read burst."""
    start: float
    end: float
    rows: int             # unique rows actually read
    bytes: int
    requested: int = 0    # ids requested before cross-query dedup


@dataclasses.dataclass
class FlashStats:
    reads: int = 0
    rows_read: int = 0
    bytes_read: int = 0
    rows_requested: int = 0
    read_s: float = 0.0
    events: list = dataclasses.field(default_factory=list)
    max_events: int = 4096
    dropped_events: int = 0

    def reset(self) -> None:
        self.reads = 0
        self.rows_read = 0
        self.bytes_read = 0
        self.rows_requested = 0
        self.read_s = 0.0
        self.events.clear()
        self.dropped_events = 0

    def record(self, ev: ReadEvent) -> None:
        self.read_s += ev.end - ev.start
        if len(self.events) >= self.max_events:
            drop = self.max_events // 2
            del self.events[:drop]
            self.dropped_events += drop
        self.events.append(ev)


# rows per arena extent: big enough that the extent table stays small, small
# enough that partial tail blocks don't waste a chunk.
ROWS_PER_EXTENT = 4096


class FlashTier:
    """Full-precision vectors behind a file-backed mmap, addressed by id.

    ``epoch`` mirrors the lifecycle contract of ``TieredPostings``: each
    index version gets its own flash file, released when the version
    manager retires the epoch.
    """

    def __init__(self, vectors: np.ndarray, path: Optional[str] = None,
                 *, arena: Optional[ChunkArena] = None,
                 name: str = "flash", epoch: int = 0):
        x = np.ascontiguousarray(np.asarray(vectors, np.float32))
        self.n, self.dim = x.shape
        self.epoch = int(epoch)
        self.name = str(name)
        self.released = False
        self.stats = FlashStats()
        if path is None:
            fd, path = tempfile.mkstemp(
                prefix=f"{self.name}-e{self.epoch}-", suffix=".f32")
            os.close(fd)
        self.path = path
        mm = np.memmap(path, dtype=np.float32, mode="w+",
                       shape=(self.n, self.dim))
        mm[:] = x
        mm.flush()
        del mm
        # reopen read-only: serving must never scribble on the flash copy
        self._mm = np.memmap(path, dtype=np.float32, mode="r",
                             shape=(self.n, self.dim))
        self._arena = arena
        self.extents: List[Extent] = []
        if arena is not None:
            n_ext = -(-self.n // ROWS_PER_EXTENT)
            self.extents = arena.allocate_index(
                f"{self.name}-e{self.epoch}", n_ext,
                ROWS_PER_EXTENT * self.row_bytes)

    @property
    def row_bytes(self) -> int:
        return self.dim * 4

    @property
    def nbytes(self) -> int:
        """Live payload bytes (the SSD term of the cost model)."""
        return self.n * self.row_bytes

    def release(self) -> None:
        """Drop the mmap, delete the backing file, return arena chunks.
        Idempotent; a read after release fails loudly."""
        if self.released:
            return
        self.released = True
        self._mm = None
        if self._arena is not None:
            self._arena.release_index(f"{self.name}-e{self.epoch}")
            self._arena = None
        try:
            os.unlink(self.path)
        except OSError:
            pass

    def read(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Read the f32 rows for a batch of candidate ids.

        ``ids`` is any-shape int; negative ids (candidate padding) are
        skipped.  Returns (uids (U,) the unique non-negative ids read,
        rows (U, D) f32) — callers remap through uids, mirroring the hot
        tier's union-dedup so a candidate shared across queries costs one
        flash read per burst.
        """
        if self.released:
            raise RuntimeError(
                f"read on released flash tier (epoch {self.epoch})")
        t0 = time.perf_counter()
        flat = np.asarray(ids).reshape(-1)
        requested = int((flat >= 0).sum())
        uids = np.unique(flat[flat >= 0]).astype(np.int64)
        rows = np.array(self._mm[uids])  # materialize: touch the "device"
        t1 = time.perf_counter()
        nb = int(rows.nbytes)
        self.stats.reads += 1
        self.stats.rows_read += int(uids.size)
        self.stats.bytes_read += nb
        self.stats.rows_requested += requested
        self.stats.record(ReadEvent(t0, t1, int(uids.size), nb,
                                    requested=requested))
        return uids, rows
