"""HBM / host-memory tiering (the DRAM:SSD = 1:20 capacity split, adapted).

The paper keeps centroids + models in DRAM and posting lists on SSD.  On a
TPU pod the analogous hierarchy is device HBM (fast, small) over host DRAM
(large, behind PCIe).  ``TieredPostings`` keeps the posting payload in host
memory (numpy) and streams only the probed clusters to the device per batch —
mirroring the paper's "read only the selected cluster lists" I/O behaviour —
while centroids and LLSP weights stay device-resident.

Two modes:
* ``resident`` — postings fully device-resident (the all-HBM fast path used
  when the index fits; this is what the sharded engine shards over `model`).
* ``streamed`` — postings host-resident; ``fetch(cids)`` gathers the union of
  probed clusters on host and device_puts one packed tensor (one "doorbell
  batch" per query batch).

The byte counters feed the Fig.-18 bandwidth-utilization analogue: achieved
bytes moved vs the tier's peak bandwidth.  Per-fetch stage timestamps
(``TierStats.events``) feed the serving-runtime overlap analysis
(runtime/pipeline.py): they let the bench *measure* that batch i+1's
gather/stream interval lands inside batch i's scan-in-flight interval
instead of asserting it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class FetchEvent:
    """Wall-clock stamps + union accounting of one fetch: host gather, then
    device stream.  ``union_bytes`` counts the payload of the REAL union
    rows only (no sentinel, no bucket padding) — the per-batch quantity
    locality-aware batch formation minimizes, measured where the gather
    happens rather than inferred from probe counts upstream."""
    gather_start: float
    gather_end: float     # union gather materialized in host memory
    stream_end: float     # packed tensors handed to the device (device_put)
    rows: int             # packed rows streamed (incl. sentinel/pad rows)
    bytes: int
    clusters_requested: int = 0   # live probe slots across the batch
    clusters_union: int = 0       # after cross-query dedup (= gather rows)
    union_bytes: int = 0          # payload bytes of the deduped union


@dataclasses.dataclass
class TierStats:
    bytes_streamed: int = 0
    union_bytes_streamed: int = 0  # sum of per-fetch union_bytes (excludes
                                   # pad/sentinel rows — the locality metric)
    batches: int = 0
    clusters_fetched: int = 0
    clusters_deduped: int = 0
    gather_s: float = 0.0          # cumulative host union-gather seconds
    stream_s: float = 0.0          # cumulative host->device stream seconds
    events: list = dataclasses.field(default_factory=list)
    max_events: int = 4096         # ring-bounded so serving daemons don't grow
    dropped_events: int = 0        # ring evictions — nonzero means ``events``
                                   # is a truncated window, not the full run
                                   # (overlap analyses must check this)

    def reset(self) -> None:
        self.bytes_streamed = 0
        self.union_bytes_streamed = 0
        self.batches = 0
        self.clusters_fetched = 0
        self.clusters_deduped = 0
        self.gather_s = 0.0
        self.stream_s = 0.0
        self.events.clear()
        self.dropped_events = 0

    def record(self, ev: FetchEvent) -> None:
        self.gather_s += ev.gather_end - ev.gather_start
        self.stream_s += ev.stream_end - ev.gather_end
        if len(self.events) >= self.max_events:
            drop = self.max_events // 2
            del self.events[:drop]
            self.dropped_events += drop
        self.events.append(ev)


def _plan_union(cids: np.ndarray, mask: Optional[np.ndarray],
                lut: np.ndarray, n_clusters: int,
                pad_rows: Optional[int], bucket: int):
    """Shared fetch planning: dedup the probed clusters across the batch and
    build the (B, P) remap into the packed row space.

    Returns (wanted (U,) unique cluster ids, u, rows, remap) where ``rows``
    is U + 1 sentinel, quantized up to ``bucket`` / ``pad_rows`` — the jit
    shape contract both the f32 and the quantized tier obey identically."""
    cids = np.asarray(cids)
    if mask is None:
        mask = np.ones_like(cids, dtype=bool)
    live = np.asarray(mask) & (cids >= 0)
    wanted = np.unique(cids[live])
    u = int(wanted.size)
    sentinel = u
    rows = max(u + 1, int(pad_rows or 0))
    rows = -(-rows // max(bucket, 1)) * max(bucket, 1)
    lut[wanted] = np.arange(u)
    remap = np.where(live, lut[np.clip(cids, 0, n_clusters - 1)], sentinel)
    return wanted, u, rows, remap.astype(np.int32), live


class TieredPostings:
    """Host-resident posting store with batched device streaming.

    ``epoch`` tags the tier with the index version it backs (lifecycle
    runtime): every epoch gets its own tier, and :meth:`release` frees the
    host payload when the epoch retires — called only after the version
    manager has seen the epoch's last in-flight batch harvest, so a live
    gather can never race the free.
    """

    def __init__(self, postings: np.ndarray, posting_ids: np.ndarray,
                 epoch: int = 0):
        self.postings = np.ascontiguousarray(postings)
        self.posting_ids = np.ascontiguousarray(posting_ids)
        self.epoch = int(epoch)
        self.released = False
        self.stats = TierStats()
        # Remap LUT hoisted out of fetch(): one reusable O(n_clusters) buffer
        # instead of a fresh allocation per call.  Only entries of the current
        # union are ever read back (masked probes bypass the LUT entirely via
        # the sentinel), so stale entries from earlier fetches are harmless.
        self._lut = np.zeros(self.postings.shape[0], dtype=np.int64)

    def release(self) -> None:
        """Drop the host payload (retired-epoch reclamation).  Idempotent;
        a fetch after release is a routing bug and fails loudly."""
        self.released = True
        self.postings = None
        self.posting_ids = None
        self._lut = None

    @property
    def cluster_bytes(self) -> int:
        return int(
            self.postings[0].nbytes + self.posting_ids[0].nbytes
        )

    def fetch(
        self,
        cids: np.ndarray,
        mask: Optional[np.ndarray] = None,
        pad_rows: Optional[int] = None,
        bucket: int = 1,
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Gather the union of probed clusters and stream them once.

        cids: (B, P) int32; mask: (B, P) bool.  Returns
        (packed_postings (R, L, D), packed_ids (R, L), remap (B, P)) with
        R = union size U plus one sentinel row, rounded up to a multiple of
        ``bucket`` and to at least ``pad_rows`` when given — callers that
        jit over the packed tensors quantize R to bound their compile
        cache.  remap[b, p] indexes into the packed tensors; masked or
        negative probes map to the SENTINEL row (all ids -1, zero payload)
        so downstream id-masking drops them even if a caller forgets the
        probe mask.  Duplicate clusters across queries are fetched once
        (the paper's burst-overlap observation, §6.2).
        """
        if self.released:
            raise RuntimeError(
                f"fetch on released tier (epoch {self.epoch}): a batch was "
                f"routed to a retired index version")
        t0 = time.perf_counter()
        wanted, u, rows, remap, live = _plan_union(
            cids, mask, self._lut, self.postings.shape[0], pad_rows, bucket)
        c, l, d = self.postings.shape
        # single-copy gather: np.take writes straight into the packed buffer
        # (no (U, L, D) temporary), and sentinel/pad payload rows stay
        # uninitialized — their ids are -1, which every consumer masks on.
        packed = np.empty((rows, l, d), dtype=self.postings.dtype)
        np.take(self.postings, wanted, axis=0, out=packed[:u])
        packed_ids = np.full((rows, l), -1, dtype=self.posting_ids.dtype)
        np.take(self.posting_ids, wanted, axis=0, out=packed_ids[:u])
        t1 = time.perf_counter()
        dev_packed = jnp.asarray(packed)
        dev_ids = jnp.asarray(packed_ids)
        dev_remap = jnp.asarray(remap.astype(np.int32))
        t2 = time.perf_counter()
        nbytes = int(packed.nbytes + packed_ids.nbytes)
        requested = int(live.sum())
        union_bytes = u * self.cluster_bytes
        self.stats.bytes_streamed += nbytes
        self.stats.union_bytes_streamed += union_bytes
        self.stats.batches += 1
        self.stats.clusters_fetched += requested
        self.stats.clusters_deduped += u
        self.stats.record(FetchEvent(t0, t1, t2, rows, nbytes,
                                     clusters_requested=requested,
                                     clusters_union=u,
                                     union_bytes=union_bytes))
        return dev_packed, dev_ids, dev_remap


class QuantizedTieredPostings:
    """Host hot tier over the int8-residual payload (core/quantize.py).

    The paper's cost thesis made concrete: the first-pass payload resident in
    host memory is q8 codes + per-slot norms + ids (~1/4 the f32 bytes), and
    the f32 vectors demote to the flash tier (storage/flash_tier.py) where
    only re-rank candidates touch them.  ``fetch`` speaks the same union /
    sentinel / remap / bucket contract as :class:`TieredPostings` but packs
    five tensors: (q8 (R, L, D) int8, scale (R, 1, 1), norm2 (R, L),
    cluster centroids (R, D), ids (R, L)) — the centroids ride along because
    the residual distance form needs the owning centroid per packed row.
    """

    quantized = True

    def __init__(self, q8: np.ndarray, scale: np.ndarray, norm2: np.ndarray,
                 centroids: np.ndarray, posting_ids: np.ndarray,
                 epoch: int = 0):
        self.q8 = np.ascontiguousarray(q8)
        # store scale flat (C,); re-expanded per packed row at fetch
        self.scale = np.ascontiguousarray(
            np.asarray(scale, np.float32).reshape(-1))
        self.norm2 = np.ascontiguousarray(np.asarray(norm2, np.float32))
        self.centroids = np.ascontiguousarray(
            np.asarray(centroids, np.float32))
        self.posting_ids = np.ascontiguousarray(posting_ids)
        self.epoch = int(epoch)
        self.released = False
        self.stats = TierStats()
        self._lut = np.zeros(self.q8.shape[0], dtype=np.int64)

    def release(self) -> None:
        self.released = True
        self.q8 = None
        self.scale = None
        self.norm2 = None
        self.centroids = None
        self.posting_ids = None
        self._lut = None

    @property
    def cluster_bytes(self) -> int:
        return int(self.q8[0].nbytes + self.norm2[0].nbytes
                   + self.posting_ids[0].nbytes + self.scale[0].nbytes
                   + self.centroids[0].nbytes)

    def nbytes(self) -> int:
        """Hot-tier resident payload bytes (the DRAM term of the cost model)."""
        return int(self.q8.nbytes + self.scale.nbytes + self.norm2.nbytes
                   + self.posting_ids.nbytes + self.centroids.nbytes)

    def fetch(
        self,
        cids: np.ndarray,
        mask: Optional[np.ndarray] = None,
        pad_rows: Optional[int] = None,
        bucket: int = 1,
    ):
        """Union-gather the probed clusters' quantized payload.

        Returns (q8 (R, L, D), scale (R, 1, 1), norm2 (R, L), cents (R, D),
        ids (R, L), remap (B, P)).  Sentinel/pad rows carry ids=-1, zero
        norms and scale=1 so downstream id-masking drops them; the q8
        payload of pad rows stays uninitialized (never read past the mask).
        """
        if self.released:
            raise RuntimeError(
                f"fetch on released tier (epoch {self.epoch}): a batch was "
                f"routed to a retired index version")
        t0 = time.perf_counter()
        wanted, u, rows, remap, live = _plan_union(
            cids, mask, self._lut, self.q8.shape[0], pad_rows, bucket)
        c, l, d = self.q8.shape
        packed_q8 = np.empty((rows, l, d), dtype=self.q8.dtype)
        np.take(self.q8, wanted, axis=0, out=packed_q8[:u])
        packed_scale = np.ones((rows,), dtype=np.float32)
        np.take(self.scale, wanted, axis=0, out=packed_scale[:u])
        packed_norm2 = np.zeros((rows, l), dtype=np.float32)
        np.take(self.norm2, wanted, axis=0, out=packed_norm2[:u])
        packed_cent = np.zeros((rows, d), dtype=np.float32)
        np.take(self.centroids, wanted, axis=0, out=packed_cent[:u])
        packed_ids = np.full((rows, l), -1, dtype=self.posting_ids.dtype)
        np.take(self.posting_ids, wanted, axis=0, out=packed_ids[:u])
        t1 = time.perf_counter()
        out = (jnp.asarray(packed_q8),
               jnp.asarray(packed_scale).reshape(rows, 1, 1),
               jnp.asarray(packed_norm2),
               jnp.asarray(packed_cent),
               jnp.asarray(packed_ids),
               jnp.asarray(remap))
        t2 = time.perf_counter()
        nbytes = int(packed_q8.nbytes + packed_scale.nbytes
                     + packed_norm2.nbytes + packed_cent.nbytes
                     + packed_ids.nbytes)
        requested = int(live.sum())
        union_bytes = u * self.cluster_bytes
        self.stats.bytes_streamed += nbytes
        self.stats.union_bytes_streamed += union_bytes
        self.stats.batches += 1
        self.stats.clusters_fetched += requested
        self.stats.clusters_deduped += u
        self.stats.record(FetchEvent(t0, t1, t2, rows, nbytes,
                                     clusters_requested=requested,
                                     clusters_union=u,
                                     union_bytes=union_bytes))
        return out
