"""HBM / host-memory tiering (the DRAM:SSD = 1:20 capacity split, adapted).

The paper keeps centroids + models in DRAM and posting lists on SSD.  On a
TPU pod the analogous hierarchy is device HBM (fast, small) over host DRAM
(large, behind PCIe).  ``TieredPostings`` keeps the posting payload in host
memory (numpy) and streams only the probed clusters to the device per batch —
mirroring the paper's "read only the selected cluster lists" I/O behaviour —
while centroids and LLSP weights stay device-resident.

Two modes:
* ``resident`` — postings fully device-resident (the all-HBM fast path used
  when the index fits; this is what the sharded engine shards over `model`).
* ``streamed`` — postings host-resident; ``fetch(cids)`` gathers the union of
  probed clusters on host and device_puts one packed tensor (one "doorbell
  batch" per query batch).

The byte counters feed the Fig.-18 bandwidth-utilization analogue: achieved
bytes moved vs the tier's peak bandwidth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TierStats:
    bytes_streamed: int = 0
    batches: int = 0
    clusters_fetched: int = 0
    clusters_deduped: int = 0

    def reset(self) -> None:
        self.bytes_streamed = 0
        self.batches = 0
        self.clusters_fetched = 0
        self.clusters_deduped = 0


class TieredPostings:
    """Host-resident posting store with batched device streaming."""

    def __init__(self, postings: np.ndarray, posting_ids: np.ndarray):
        self.postings = np.ascontiguousarray(postings)
        self.posting_ids = np.ascontiguousarray(posting_ids)
        self.stats = TierStats()

    @property
    def cluster_bytes(self) -> int:
        return int(
            self.postings[0].nbytes + self.posting_ids[0].nbytes
        )

    def fetch(
        self, cids: np.ndarray, mask: Optional[np.ndarray] = None
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Gather the union of probed clusters and stream them once.

        cids: (B, P) int32; mask: (B, P) bool.  Returns
        (packed_postings (U, L, D), packed_ids (U, L), remap (B, P)) where
        remap[b, p] indexes into the packed tensors (0 for masked probes,
        whose ids are -1 in packed row 0 only if masked — callers must apply
        the mask).  Duplicate clusters across queries are fetched once
        (the paper's burst-overlap observation, §6.2).
        """
        cids = np.asarray(cids)
        if mask is None:
            mask = np.ones_like(cids, dtype=bool)
        mask = np.asarray(mask)
        wanted = np.unique(cids[mask])
        wanted = wanted[wanted >= 0]
        if wanted.size == 0:
            wanted = np.zeros((1,), dtype=np.int64)
        lut = np.zeros(self.postings.shape[0], dtype=np.int64)
        lut[wanted] = np.arange(wanted.size)
        remap = lut[np.clip(cids, 0, None)]
        packed = self.postings[wanted]
        packed_ids = self.posting_ids[wanted]
        self.stats.bytes_streamed += int(packed.nbytes + packed_ids.nbytes)
        self.stats.batches += 1
        self.stats.clusters_fetched += int(mask.sum())
        self.stats.clusters_deduped += int(wanted.size)
        return (
            jnp.asarray(packed),
            jnp.asarray(packed_ids),
            jnp.asarray(remap.astype(np.int32)),
        )
