"""Retrieval-model + Helmsman integration (paper §2.1 Rec/Ads pipeline).

Trains a reduced MIND multi-interest retrieval model for a few hundred steps
on synthetic click logs, exports the learned item-embedding table, builds a
Helmsman index OVER THE LEARNED EMBEDDINGS (this is exactly the paper's
"embedding models are updated in batches ... up to ten thousand index
rebuilds per day" flow), and serves multi-interest retrieval through the IVF
engine, comparing recall and probe cost against exhaustive scoring.

    PYTHONPATH=src python examples/train_retrieval.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.build.pipeline import BuildConfig, build_index
from repro.core.distance import recall_at_k
from repro.core.search import SearchConfig, serve_step
from repro.data import recsys_batch
from repro.models.recsys import RecSysConfig, init_params, make_train_step
from repro.models.recsys.models import capsule_routing, retrieval_scores
from repro.optim import adamw


def make_structured_batch(b, n_items, seq_len, n_groups=32, seed=0):
    """Synthetic logs with latent interest groups: each user draws history
    from a few groups; the label is 1 iff the target item belongs to one of
    the user's groups — so MIND must learn the group structure."""
    rng = np.random.default_rng(seed)
    group_of = np.arange(n_items) % n_groups
    user_groups = rng.integers(0, n_groups, size=(b, 3))
    hist = np.empty((b, seq_len), np.int32)
    for i in range(b):
        gs = user_groups[i][rng.integers(0, 3, seq_len)]
        hist[i] = gs + n_groups * rng.integers(0, n_items // n_groups, seq_len)
    pos = rng.random(b) < 0.5
    target = np.where(
        pos,
        user_groups[np.arange(b), rng.integers(0, 3, b)]
        + n_groups * rng.integers(0, n_items // n_groups, b),
        rng.integers(0, n_items, b),
    ).astype(np.int32)
    labels = (group_of[target][:, None] == user_groups).any(1).astype(np.float32)
    return {"sparse_ids": target[:, None], "hist_ids": hist,
            "hist_len": np.full(b, seq_len, np.int32), "labels": labels}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--items", type=int, default=8192)
    args = ap.parse_args()

    cfg = RecSysConfig("mind", "mind", n_sparse=1, embed_dim=32,
                       table_rows=args.items, seq_len=20, n_interests=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    # retrieval towers need O(1)-norm embeddings: the capsule squash kills
    # gradients at tiny norms (default table init is 1/sqrt(rows))
    params["table"] = params["table"] * (0.5 * np.sqrt(args.items) / np.sqrt(cfg.embed_dim))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt_cfg=opt_cfg))
    opt = adamw.init(params)

    t0 = time.perf_counter()
    for s in range(args.steps):
        batch = make_structured_batch(256, args.items, cfg.seq_len, seed=s)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, batch)
        if s % 50 == 0:
            print(f"[train] step {s:4d} loss={float(m['loss']):.4f} "
                  f"({time.perf_counter()-t0:.1f}s)")
    print(f"[train] {args.steps} steps in {time.perf_counter()-t0:.1f}s "
          f"(final loss {float(m['loss']):.3f})")

    # ---- daily-rebuild flow: index the LEARNED item embeddings ------------
    items = np.asarray(params["table"], dtype=np.float32)
    bcfg = BuildConfig(max_cluster_size=64, cluster_len=96,
                       coarse_per_task=2048, n_workers=2)
    # training queries for LLSP: user interest vectors from real batches
    qs = []
    for s in range(4):
        b = make_structured_batch(64, args.items, cfg.seq_len, seed=999 + s)
        hist = jnp.asarray(params["table"])[jnp.asarray(b["hist_ids"])]
        hmask = jnp.arange(cfg.seq_len)[None, :] < jnp.asarray(b["hist_len"])[:, None]
        interests = capsule_routing(hist, hmask, params["bilinear"], cfg)
        qs.append(np.asarray(interests).reshape(-1, cfg.embed_dim))
    queries = np.concatenate(qs)
    with tempfile.TemporaryDirectory() as wd:
        t0 = time.perf_counter()
        index, _, report = build_index(items, bcfg, wd)
        print(f"[rebuild] {report.n_clusters} clusters over learned "
              f"embeddings in {time.perf_counter()-t0:.1f}s")

        # ---- serve: each interest vector is a Helmsman query --------------
        k = 50
        qj = jnp.asarray(queries[:256])
        out = serve_step(index, None, qj,
                         jnp.full((256,), k, jnp.int32),
                         SearchConfig(k=k, nprobe_max=32, pruning="fixed",
                                      eps=0.2, use_kernel=False))
        # exhaustive oracle over all items
        _, oracle_ids = retrieval_scores(qj, jnp.asarray(items), k=k)
        # retrieval_scores ranks by dot; Helmsman by L2 — compare on L2 truth
        from repro.core.ivf import brute_force_topk
        _, true_ids = brute_force_topk(jnp.asarray(items), qj, k)
        r = recall_at_k(np.asarray(out["ids"]), np.asarray(true_ids))
        scanned = float(np.asarray(out["nprobe"]).mean()) * index.cluster_len
        print(f"[serve] interest-query recall@{k} = {r:.3f} scanning "
              f"{scanned:.0f}/{args.items} items "
              f"({scanned/args.items:.1%} of an exhaustive scan)")


if __name__ == "__main__":
    main()
