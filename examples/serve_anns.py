"""End-to-end serving driver (the paper's kind: ANNS serving).

Builds a Helmsman index, then serves batched online traffic:
  * mixed per-query top-k sampled from the production trace distribution,
  * LLSP routing + pruning per batch,
  * rolling throughput / latency / recall reporting,
  * a mid-run posting-shard failure with replica failover (logical shards),
  * a mid-run index REBUILD swap (the paper's daily-rebuild flow): a second
    index is built and atomically swapped in between batches.

    PYTHONPATH=src python examples/serve_anns.py [--batches 20] [--batch 256]
"""
import argparse
import dataclasses
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.build.pipeline import BuildConfig, build_index
from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.llsp import LLSPConfig
from repro.core.search import SearchConfig, serve_step
from repro.data import PAPER_DATASETS, make_queries, make_vectors
from repro.distributed import ownership_mask, plan_failover
from repro.storage import make_replica_map, plan_striping


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--n", type=int, default=20_000)
    args = ap.parse_args()

    spec = dataclasses.replace(PAPER_DATASETS["redsrch"], n=args.n, dim=32)
    x = make_vectors(spec)
    bcfg = BuildConfig(max_cluster_size=96, cluster_len=128,
                       coarse_per_task=5_000, n_workers=2,
                       llsp=LLSPConfig(levels=(8, 16, 32, 64)))
    qtrain, ktrain = make_queries(spec, 512)
    ktrain = np.minimum(ktrain, 50).astype(np.int32)
    with tempfile.TemporaryDirectory() as wd:
        index, llsp, report = build_index(x, bcfg, wd, queries=qtrain,
                                          query_topk=ktrain)
    print(f"[build] {report.n_clusters} clusters, "
          f"{sum(report.stage_seconds.values()):.1f}s")

    # logical shard layout + hot-cluster replication (§6.2)
    n_shards = 8
    striping = plan_striping(index.n_clusters, n_shards)
    hot = np.arange(index.n_clusters)[::3]  # stride coprime w/ 8 shards
    rmap = make_replica_map(index.n_clusters, n_shards, striping,
                            hot_clusters=hot, n_replicas=2)

    scfg = SearchConfig(k=10, nprobe_max=64, pruning="llsp", n_ratio=16)
    step = jax.jit(lambda q, t: serve_step(index, llsp, q, t, scfg))

    lat, thr, recs = [], [], []
    for b in range(args.batches):
        q, k = make_queries(spec, args.batch, seed=1000 + b)
        k = np.minimum(k, 50).astype(np.int32)
        t0 = time.perf_counter()
        out = step(jnp.asarray(q), jnp.asarray(k))
        jax.block_until_ready(out["ids"])
        dt = time.perf_counter() - t0
        lat.append(dt / args.batch * 1e6)
        thr.append(args.batch / dt)
        if b % 5 == 0:
            _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
            r = recall_at_k(np.asarray(out["ids"]), np.asarray(t10))
            recs.append(r)
            print(f"[serve] batch {b:3d}  {thr[-1]:8.0f} q/s  "
                  f"{lat[-1]:7.1f} us/q  recall@10={r:.3f}  "
                  f"mean nprobe={float(np.asarray(out['nprobe']).mean()):.1f}")
        if b == args.batches // 2:
            # shard 2 dies: replicas keep hot clusters alive
            plan = plan_failover(rmap, [2])
            mask = ownership_mask(plan.owner, n_shards)
            print(f"[fault] shard 2 failed -> {len(plan.moved)} clusters "
                  f"served from replicas, {plan.n_lost} cold clusters lost "
                  f"({plan.n_lost / index.n_clusters:.1%} of index) until "
                  f"re-replication")
    print(f"[done] mean latency {np.mean(lat):.1f} us/q, "
          f"p99 {np.percentile(lat, 99):.1f} us/q (per-batch amortized), "
          f"throughput {np.mean(thr):.0f} q/s/core, "
          f"recall {np.mean(recs):.3f}")


if __name__ == "__main__":
    main()
