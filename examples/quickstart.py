"""Quickstart: build a Helmsman index and search it, in ~30 lines of API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.build.pipeline import BuildConfig, build_index
from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.llsp import LLSPConfig
from repro.core.search import SearchConfig, serve_step
from repro.data import PAPER_DATASETS, make_queries, make_vectors
import dataclasses
import tempfile

# 1. a clustered corpus + production-like queries (per-query top-k)
spec = dataclasses.replace(PAPER_DATASETS["sift"], n=20_000, dim=32)
x = make_vectors(spec)
queries, topk = make_queries(spec, 256)
topk = np.minimum(topk, 50).astype(np.int32)

# 2. three-stage build: GPU-analogue coarse k-means -> elastic fine split +
#    closure assignment -> merge + LLSP training
cfg = BuildConfig(
    max_cluster_size=96, cluster_len=128, coarse_per_task=5_000, n_workers=2,
    llsp=LLSPConfig(levels=(8, 16, 32, 64), recall_target=0.9),
)
with tempfile.TemporaryDirectory() as workdir:
    index, llsp, report = build_index(x, cfg, workdir,
                                      queries=queries, query_topk=topk)
print(f"built {report.n_clusters} clusters "
      f"(replication {report.replication:.2f}x) "
      f"in {sum(report.stage_seconds.values()):.1f}s")

# 3. serve a batch: router -> centroid scan -> leveling pruning -> one
#    batched posting scan -> dedup top-k
out = serve_step(
    index, llsp, jnp.asarray(queries), jnp.asarray(topk),
    SearchConfig(k=10, nprobe_max=64, pruning="llsp", n_ratio=16),
)

_, true10 = brute_force_topk(jnp.asarray(x), jnp.asarray(queries), 10)
print(f"recall@10 = {recall_at_k(np.asarray(out['ids']), np.asarray(true10)):.3f}  "
      f"mean nprobe = {float(np.asarray(out['nprobe']).mean()):.1f} / 64")
